"""Hymba-style hybrid block (arXiv:2411.13676): attention heads and mamba
(selective-SSM) heads run in PARALLEL on the same block input; their normed
outputs are averaged, then a standard SwiGLU MLP follows.

Attention uses sliding-window everywhere except the first/middle/last layers
(global), per the Hymba recipe.  The SSM branch carries (conv window, ssm
state) caches with snapshot-ring rollback like ssm.py; the attention branch
rolls back via the logical cache_mask — both stay in sync through the shared
ModelState buffers (the paper's §4.4 requirement for heterogeneous chains).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from . import kv_cache as kvc
from . import layers as nn
from .config import ModelConfig
from . import transformer as tf
from .ssm import SNAP_SLOTS


def _inner(cfg):
    return cfg.d_model * (cfg.ssm.expand if cfg.ssm else 2)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer_params(key, cfg: ModelConfig):
    dt = cfg.dtype
    d = cfg.d_model
    inner = _inner(cfg)
    N = cfg.ssm.state_size
    ks = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(inner)
    p = {
        "ln1": nn.init_rmsnorm(d, dt)[0],
        "attn": nn.init_attention(ks[0], cfg, dt)[0],
        "attn_norm": nn.init_rmsnorm(d, dt)[0],
        "ssm_norm": nn.init_rmsnorm(d, dt)[0],
        "in_proj": (jax.random.normal(ks[1], (d, 2 * inner)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (4, inner)) * 0.5).astype(dt),
        "w_dt": (jax.random.normal(ks[3], (inner, inner)) * si * 0.1
                 ).astype(jnp.float32),
        "b_dt": jnp.log(jnp.expm1(jnp.full((inner,), 0.01))).astype(jnp.float32),
        "w_B": (jax.random.normal(ks[4], (inner, N)) * si).astype(jnp.float32),
        "w_C": (jax.random.normal(ks[5], (inner, N)) * si).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (inner, 1))),
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": (jax.random.normal(ks[6], (inner, d)) * si).astype(dt),
        "beta_attn": jnp.ones((), jnp.float32),
        "beta_ssm": jnp.ones((), jnp.float32),
        "ln2": nn.init_rmsnorm(d, dt)[0],
        "mlp": nn.init_swiglu(ks[7], d, cfg.d_ff, dt)[0],
    }
    return p


def _layer_axes(cfg: ModelConfig):
    L = ("layers",)
    return {
        "ln1": {"scale": L + ("embed",)},
        "attn": {
            "q": {"w": L + ("embed", "heads")},
            "k": {"w": L + ("embed", "kv_heads")},
            "v": {"w": L + ("embed", "kv_heads")},
            "o": {"w": L + ("heads", "embed")},
        },
        "attn_norm": {"scale": L + ("embed",)},
        "ssm_norm": {"scale": L + ("embed",)},
        "in_proj": L + ("embed", "ssm_inner"),
        "conv_w": L + ("conv", "ssm_inner"),
        "w_dt": L + ("ssm_inner", "ssm_inner"),
        "b_dt": L + ("ssm_inner",),
        "w_B": L + ("ssm_inner", "ssm_state"),
        "w_C": L + ("ssm_inner", "ssm_state"),
        "A_log": L + ("ssm_inner", "ssm_state"),
        "D": L + ("ssm_inner",),
        "out_proj": L + ("ssm_inner", "embed"),
        "beta_attn": L, "beta_ssm": L,
        "ln2": {"scale": L + ("embed",)},
        "mlp": {"gate": {"w": L + ("embed", "mlp")},
                "up": {"w": L + ("embed", "mlp")},
                "down": {"w": L + ("mlp", "embed")}},
    }


def param_axes(cfg: ModelConfig):
    return {
        "embed": ("vocab", "embed"),
        "blocks": _layer_axes(cfg),
        "final_norm": {"scale": ("embed",)},
    }


def init(key, cfg: ModelConfig):
    dt = cfg.dtype
    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dt),
        "blocks": jax.vmap(partial(_init_layer_params, cfg=cfg))(layer_keys),
        "final_norm": nn.init_rmsnorm(cfg.d_model, dt)[0],
    }
    return params, param_axes(cfg)


def layer_flags(cfg: ModelConfig):
    """Hymba: global attention on first, middle, last layer; SWA elsewhere."""
    L = cfg.num_layers
    glb = {0, L // 2, L - 1}
    return jnp.array([i in glb for i in range(L)], jnp.bool_)


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               with_snaps: bool = False):
    inner = _inner(cfg)
    N = cfg.ssm.state_size
    L = cfg.num_layers
    layers = kvc.make_attn_cache(L, batch, max_len, cfg.num_kv_heads,
                                 cfg.head_dim, cfg.dtype)
    layers["ssm_h"] = jnp.zeros((L, batch, inner, N), jnp.float32)
    layers["conv"] = jnp.zeros((L, batch, 3, inner), cfg.dtype)
    if with_snaps:
        layers["snaps"] = {
            "ssm_h": jnp.zeros((SNAP_SLOTS, L, batch, inner, N), jnp.float32),
            "conv": jnp.zeros((SNAP_SLOTS, L, batch, 3, inner), cfg.dtype),
        }
    axes = kvc.attn_cache_axes()
    axes["ssm_h"] = ("layers", "batch", "ssm_inner", "ssm_state")
    axes["conv"] = ("layers", "batch", None, "ssm_inner")
    if with_snaps:
        axes["snaps"] = jax.tree.map(lambda _: None, layers["snaps"])
    return layers, axes


# ---------------------------------------------------------------------------
# Mamba branch (selective SSM), scanned over T inside the layer
# ---------------------------------------------------------------------------
def _mamba_branch(pl, cfg, x_norm, ssm_h, conv_buf, valid, collect=False):
    """x_norm: (B,T,d). Returns (y (B,T,d), ssm_h', conv_buf'[, per-step states])."""
    B, T, d = x_norm.shape
    inner = _inner(cfg)
    xz = jnp.einsum("btd,di->bti", x_norm, pl["in_proj"])
    x_ssm, z = jnp.split(xz, 2, axis=-1)                       # (B,T,inner)

    def step(carry, inp):
        h, cbuf = carry
        xt, vt = inp                                           # (B,inner),(B,)
        win = jnp.concatenate([cbuf, xt[:, None, :]], axis=1)  # (B,4,inner)
        xc = jax.nn.silu(jnp.einsum("bti,ti->bi", win.astype(jnp.float32),
                                    pl["conv_w"].astype(jnp.float32)))
        dt_ = jax.nn.softplus(xc @ pl["w_dt"] + pl["b_dt"])    # (B,inner)
        Bc = xc @ pl["w_B"]                                    # (B,N)
        Cc = xc @ pl["w_C"]
        A = -jnp.exp(pl["A_log"])                              # (inner,N)
        dA = jnp.exp(dt_[..., None] * A[None])                 # (B,inner,N)
        h_new = dA * h + (dt_ * xc)[..., None] * Bc[:, None, :]
        y = jnp.einsum("bin,bn->bi", h_new, Cc) + pl["D"] * xc
        vb = vt[:, None]
        h_out = jnp.where(vt[:, None, None], h_new, h)
        cb_out = jnp.where(vt[:, None, None],
                           jnp.concatenate([cbuf[:, 1:], xt[:, None, :]],
                                           axis=1), cbuf)
        ys = (jnp.where(vb, y, 0.0), h_out, cb_out) if collect \
            else jnp.where(vb, y, 0.0)
        return (h_out, cb_out), ys

    x_tb = jnp.swapaxes(x_ssm, 0, 1)
    v_tb = jnp.swapaxes(valid, 0, 1)
    CK = 64
    if not collect and T % CK == 0 and T >= 2 * CK:
        # chunked-remat time scan (same pathology/fix as xlstm §Perf H1)
        def chunk(carry, inp):
            return jax.lax.scan(step, carry, inp)
        chunked = jax.checkpoint(
            chunk, policy=jax.checkpoint_policies.nothing_saveable)
        (h_fin, cb_fin), ys = jax.lax.scan(
            chunked, (ssm_h, conv_buf),
            (x_tb.reshape(T // CK, CK, *x_tb.shape[1:]),
             v_tb.reshape(T // CK, CK, *v_tb.shape[1:])))
        ys = ys.reshape(T, *ys.shape[2:])
    else:
        (h_fin, cb_fin), ys = jax.lax.scan(step, (ssm_h, conv_buf),
                                           (x_tb, v_tb))
    y_tb, steps = (ys[0], (ys[1], ys[2])) if collect else (ys, None)
    y = jnp.swapaxes(y_tb, 0, 1)                               # (B,T,inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bti,id->btd", y.astype(x_norm.dtype), pl["out_proj"])
    if collect:
        return out, h_fin, cb_fin, steps  # steps: ((T,B,inner,N),(T,B,3,inner))
    return out, h_fin, cb_fin


def _block(pl, cfg, x, *, k_cached, v_cached, ssm_h, conv_buf, mask,
           q_pos, valid, write_slot=None, collect=False):
    h = nn.rmsnorm(pl["ln1"], x, cfg.rms_eps)
    # attention branch
    q, k_new, v_new = nn.attention_qkv(pl["attn"], h, cfg)
    q = tf._rope_traced(q, q_pos, jnp.float32(cfg.rope_theta), cfg.head_dim)
    k_new = tf._rope_traced(k_new, q_pos, jnp.float32(cfg.rope_theta),
                            cfg.head_dim)
    if k_cached is not None:
        ck, cv = kvc.write_kv(k_cached, v_cached, k_new, v_new, write_slot)
        attn_o = nn.gqa_attention(q, ck, cv, mask)
        new_kv = (ck, cv)
    else:
        attn_o = nn.gqa_attention(q, k_new, v_new, mask)
        new_kv = (None, None)
    attn_y = nn.attention_out(pl["attn"], attn_o)
    # mamba branch (parallel, same input)
    res = _mamba_branch(pl, cfg, h, ssm_h, conv_buf, valid, collect=collect)
    ssm_y, ssm_h2, conv2 = res[0], res[1], res[2]
    steps = res[3] if collect else None
    # normalized average fusion (Hymba)
    fused = (nn.rmsnorm(pl["attn_norm"], attn_y, cfg.rms_eps)
             * pl["beta_attn"].astype(x.dtype)
             + nn.rmsnorm(pl["ssm_norm"], ssm_y, cfg.rms_eps)
             * pl["beta_ssm"].astype(x.dtype)) * 0.5
    x = x + fused
    h2 = nn.rmsnorm(pl["ln2"], x, cfg.rms_eps)
    return x + nn.swiglu(pl["mlp"], h2), new_kv, ssm_h2, conv2, steps


def _forward(params, cfg, state, tokens, valid, m_full, m_win, q_pos,
             slot, with_cache: bool):
    x = tf._embed(params, cfg, tokens)
    is_global = layer_flags(cfg)
    xs = {"pl": params["blocks"], "g": is_global}
    if with_cache:
        xs.update({"ck": state.layers["k"], "cv": state.layers["v"],
                   "h": state.layers["ssm_h"], "cb": state.layers["conv"]})
    else:
        B, T = tokens.shape
        inner, N = _inner(cfg), cfg.ssm.state_size
        L = cfg.num_layers
        xs.update({"h": jnp.zeros((L, B, inner, N), jnp.float32),
                   "cb": jnp.zeros((L, B, 3, inner), cfg.dtype)})

    collect = with_cache and state is not None and "snaps" in state.layers

    def body(x, s):
        mask = jnp.where(s["g"], m_full, m_win)
        x, (ck, cv), h2, cb2, steps = _block(
            s["pl"], cfg, x, k_cached=s.get("ck"), v_cached=s.get("cv"),
            ssm_h=s["h"], conv_buf=s["cb"], mask=mask, q_pos=q_pos,
            valid=valid, write_slot=slot, collect=collect)
        out = {"h": h2, "cb": cb2}
        if ck is not None:
            out.update({"k": ck, "v": cv})
        if collect:
            out["h_steps"], out["cb_steps"] = steps
        return x, out

    # trainer path: remat each layer — the mamba time scan otherwise saves
    # every per-step (B,inner,N) state for backward (EXPERIMENTS §Perf,
    # same pathology as xlstm H1)
    if not with_cache:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.lax.scan(body, x, xs)


def forward_cached(params, cfg: ModelConfig, state: kvc.ModelState,
                   tokens, valid=None, logits_mode="all", **_ignored):
    B, T = tokens.shape
    if valid is None:
        valid = jnp.ones((B, T), jnp.bool_)
    state, q_pos, slot = kvc.append_tokens(state, tokens, valid)
    m_full = nn.build_attention_mask(state.mask, state.pos_buf, q_pos, 0)
    m_win = nn.build_attention_mask(state.mask, state.pos_buf, q_pos,
                                    cfg.sliding_window)
    x, outs = _forward(params, cfg, state, tokens, valid, m_full, m_win,
                       q_pos, slot, with_cache=True)
    new_layers = {**state.layers, "k": outs["k"], "v": outs["v"],
                  "ssm_h": outs["h"], "conv": outs["cb"]}
    if "snaps" in state.layers:
        # outs["h_steps"]: (L, T, B, inner, N); write each token's full-depth
        # SSM state into the snapshot ring at physical slot (slot + t).
        snaps = state.layers["snaps"]
        for t in range(T):
            snaps = {
                "ssm_h": kvc.snap_write(snaps["ssm_h"],
                                        outs["h_steps"][:, t], slot + t),
                "conv": kvc.snap_write(snaps["conv"],
                                       outs["cb_steps"][:, t], slot + t),
            }
        new_layers["snaps"] = snaps
    state = dataclasses.replace(state, layers=new_layers)
    if logits_mode == "none":
        return None, state
    if logits_mode == "last":
        idx = jnp.maximum(jnp.sum(valid, axis=1) - 1, 0)
        x_last = jnp.take_along_axis(
            x, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return tf._unembed(params, cfg, x_last), state
    return tf._unembed(params, cfg, x), state


def rollback_hybrid(state: kvc.ModelState, r: jnp.ndarray) -> kvc.ModelState:
    """Hybrid rollback: attention KV rolls back via cache_mask (caller uses
    kv_cache.rollback); the SSM branch restores per-row snapshots here."""
    from .ssm import _restore_leaf
    layers = state.layers
    assert "snaps" in layers
    P = state.write_ptr
    slots = ((P - 1 - r.astype(jnp.int32)) % SNAP_SLOTS).astype(jnp.int32)
    new = dict(layers)
    new["ssm_h"] = _restore_leaf(layers["snaps"]["ssm_h"],
                                 layers["ssm_h"], slots, 1 + 1)
    new["conv"] = _restore_leaf(layers["snaps"]["conv"],
                                layers["conv"], slots, 1 + 1)
    return dataclasses.replace(state, layers=new)


def forward_train(params, cfg: ModelConfig, tokens, remat=True, **_ignored):
    B, S = tokens.shape
    ar = jnp.arange(S, dtype=jnp.int32)
    pos = jnp.broadcast_to(ar[None, :], (B, S))
    causal = jnp.broadcast_to(ar[None, :, None] >= ar[None, None, :], (B, S, S))
    m_win = causal & (ar[None, None, :] > ar[None, :, None] - cfg.sliding_window)
    valid = jnp.ones((B, S), jnp.bool_)
    x, _ = _forward(params, cfg, None, tokens, valid, causal, m_win,
                    pos, None, with_cache=False)
    return tf._unembed(params, cfg, x)
