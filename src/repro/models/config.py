"""Unified model configuration for every architecture family in the pool.

Each assigned architecture gets a ``ModelConfig`` in ``repro.configs``; the
SpecRouter pool holds several ModelConfigs sharing a tokenizer/vocab.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # hidden width of each expert FFN
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    num_shared_experts: int = 0    # kimi-k2 style shared expert(s)
    d_shared: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Covers mamba-style heads (hymba) and xLSTM blocks."""
    state_size: int = 16           # N (mamba) — per-channel state
    num_ssm_heads: int = 0         # parallel SSM heads (hymba)
    conv_size: int = 4
    expand: int = 2
    # xLSTM specifics
    slstm_every: int = 0           # every k-th block is sLSTM (0 = none)
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.334


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder: the encoder is a STUB that provides
    precomputed frame embeddings; the decoder cross-attends to them."""
    num_encoder_positions: int = 1500
    d_encoder: int = 384


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """Qwen2-VL style: stub patch embeddings prepended, M-RoPE positions."""
    num_patch_tokens: int = 256
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t, h, w (pairs)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False         # qwen1.5
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = True
    max_position: int = 131072
    # sliding-window / local:global pattern (gemma3: 5 local per 1 global)
    sliding_window: int = 0        # 0 = full attention everywhere
    local_global_ratio: int = 0    # k -> k local layers then 1 global
    learned_positions: bool = False  # whisper decoder
    logit_softcap: float = 0.0     # gemma-style final logit softcap
    attn_softcap: float = 0.0
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d)
    sandwich_norm: bool = False    # gemma3: pre+post norms around attn/mlp
    qk_norm: bool = False          # gemma3: rmsnorm on q,k heads
    kv_quant: bool = False         # int8 KV cache (beyond-paper, §Perf G2)
    rope_theta_global: float = 0.0  # gemma3: different theta on global layers
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    dtype: jnp.dtype = jnp.bfloat16
    source: str = ""               # citation bracket from the assignment

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads={self.num_heads} not a multiple of "
            f"num_kv_heads={self.num_kv_heads}")

    # ---- derived -----------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def is_global_layer(self, layer_idx: int) -> bool:
        """Layer attention pattern under local:global interleave."""
        if self.local_global_ratio <= 0 or self.sliding_window <= 0:
            return True
        # k local layers then 1 global, repeating (gemma3 = 5:1)
        return (layer_idx + 1) % (self.local_global_ratio + 1) == 0

    def supports_long_context(self) -> bool:
        """Sub-quadratic-capable: SSM, hybrid, or sliding-window dense."""
        return self.arch_type in ("ssm", "hybrid") or self.sliding_window > 0

    def has_decode_step(self) -> bool:
        return True  # all assigned archs are decoder-bearing

    @property
    def supports_tree(self) -> bool:
        """Tree-structured speculation needs per-position KV that can mask
        dead branches; recurrent carries (SSM/hybrid) cannot branch."""
        return self.arch_type in ("dense", "moe", "audio", "vlm")

    @property
    def supports_paged(self) -> bool:
        """Paged KV needs a purely per-position cache; recurrent carries
        (SSM/hybrid) keep the contiguous state + snapshot rings."""
        return self.arch_type in ("dense", "moe", "audio", "vlm")

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS roofline term)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd, nh, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.arch_type == "ssm":
            s = self.ssm or SSMConfig()
            # mLSTM block: up-proj 2*pf*d, qkv over inner dim, down-proj
            inner = int(d * s.mlstm_proj_factor)
            per_layer = d * inner * 2 + 3 * inner * inner // max(1, 1) // 1
            per_layer = d * inner * 2 + 3 * inner * (inner // max(self.num_heads, 1)) * self.num_heads + inner * d
        else:
            attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
            if self.arch_type == "hybrid" and self.ssm:
                inner = d * self.ssm.expand
                attn += d * inner * 2 + inner * d + inner * self.ssm.state_size * 2
            if self.moe is not None:
                m = self.moe
                ffn = m.num_experts * 3 * d * m.d_expert + d * m.num_experts
                ffn += m.num_shared_experts * 3 * d * max(m.d_shared, m.d_expert)
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn
        if self.encdec is not None:
            per_layer += d * nh * hd * 2 + 2 * self.encdec.d_encoder * nkv * hd
        return emb + L * per_layer + d  # + final norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.num_layers
        total = self.param_count()
        all_expert = L * m.num_experts * 3 * d * m.d_expert
        active_expert = L * m.top_k * 3 * d * m.d_expert
        return total - all_expert + active_expert


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (public pool)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
