"""LanguageModel facade: one uniform interface over all architecture
families, consumed by the SpecRouter core, the trainer, the serving engine,
and the dry-run launcher.

    lm = LanguageModel(cfg)
    params, axes = lm.init(key)
    state, state_axes = lm.make_state(batch, max_len, with_snaps=...)
    logits, state = lm.prefill(params, state, tokens, **extras)
    logits, state = lm.decode(params, state, tokens, valid=..., **extras)
    state = lm.rollback(state, r)
    logits[, aux] = lm.train_logits(params, tokens, **extras)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import frontends, hybrid, kv_cache as kvc, moe, ssm, transformer as tf
from .config import ModelConfig

_FAMILY = {
    "dense": tf, "audio": tf, "vlm": tf,
    "moe": moe, "ssm": ssm, "hybrid": hybrid,
}


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.mod = _FAMILY[cfg.arch_type]

    # ---- params ------------------------------------------------------
    def init(self, key):
        return self.mod.init(key, self.cfg)

    def param_axes(self):
        return self.mod.param_axes(self.cfg)

    # ---- abstract (no-allocation) views for the dry-run ---------------
    def abstract_params(self):
        return jax.eval_shape(
            lambda k: self.init(k)[0],
            # under eval_shape the key is abstract; nothing is drawn
            jax.random.PRNGKey(0))  # speclint: disable=rng-literal-key -- abstract eval only

    def abstract_state(self, batch: int, max_len: int):
        """(ShapeDtypeStruct state, axes) without allocating the buffers."""
        state = jax.eval_shape(lambda: self.make_state(batch, max_len)[0])
        axes = self.make_state(1, 8)[1]   # axes structure is size-free
        return state, axes

    # ---- state -------------------------------------------------------
    def make_state(self, batch: int, max_len: int, with_snaps: bool = False,
                   paged: bool = False, block_size: int = 0,
                   pool_blocks: int = 0):
        """``paged=True`` builds a PagedModelState (per-row block tables
        over a shared block pool) for archs with a purely per-position
        cache; SSM/hybrid silently keep the contiguous layout (their
        recurrent carries need the snapshot-ring machinery)."""
        cfg = self.cfg
        if paged and cfg.supports_paged:
            bs = block_size or kvc.PAGE_BLOCK
            layers, axes = self.mod.make_paged_cache(
                cfg, batch, max_len, bs, pool_blocks or None)
            state = kvc.make_paged_state(batch, max_len, layers,
                                         block_size=bs,
                                         pool_blocks=pool_blocks or None)
            return state, kvc.paged_state_axes(axes, bs)
        if self.mod in (ssm, hybrid):
            layers, axes = self.mod.make_cache(cfg, batch, max_len,
                                               with_snaps=with_snaps)
        else:
            layers, axes = self.mod.make_cache(cfg, batch, max_len)
        state = kvc.make_state(batch, max_len, layers)
        state_axes = kvc.ModelState(
            token_buf=("batch", "seq"), pos_buf=("batch", "seq"),
            mask=("batch", "seq"), length=("batch",), write_ptr=(),
            layers=axes)
        return state, state_axes

    # ---- extras handling ----------------------------------------------
    def _prep(self, params, state, tokens, extras):
        """Returns (kwargs for forward_cached, state possibly updated)."""
        cfg = self.cfg
        kw: Dict[str, Any] = {}
        if cfg.arch_type == "audio":
            enc = extras.get("enc_states")
            if enc is not None and state is not None:
                xk, xv = tf.precompute_cross_kv(params, cfg, enc)
                state = dataclasses.replace(
                    state, layers={**state.layers, "cross_k": xk,
                                   "cross_v": xv})
        if cfg.arch_type == "vlm" and extras.get("mrope_positions") is not None:
            kw["mrope_positions"] = extras["mrope_positions"]
        if extras.get("input_embeds") is not None:
            kw["input_embeds"] = extras["input_embeds"]
        return kw, state

    # ---- inference -----------------------------------------------------
    def prefill(self, params, state, tokens, valid=None, logits_mode="last",
                **extras):
        kw, state = self._prep(params, state, tokens, extras)
        return self.mod.forward_cached(
            params, self.cfg, state, tokens, valid=valid,
            logits_mode=logits_mode, **kw)

    def decode(self, params, state, tokens, valid=None, logits_mode="all",
               spec_depth=None, spec_attend=None, **extras):
        kw, state = self._prep(params, state, tokens, extras)
        if spec_depth is not None or spec_attend is not None:
            # tree-structured speculation needs a per-position cache whose
            # branches can be masked independently; recurrent carries
            # (SSM/hybrid) cannot branch, so those archs stay linear-only
            if not self.cfg.supports_tree:
                raise NotImplementedError(
                    f"{self.cfg.arch_type} models cannot decode token trees")
            kw["spec_depth"] = spec_depth
            kw["spec_attend"] = spec_attend
        return self.mod.forward_cached(
            params, self.cfg, state, tokens, valid=valid,
            logits_mode=logits_mode, **kw)

    # ---- rollback (paper §4.4; SSM snapshot adaptation DESIGN §5) ------
    def rollback(self, state: kvc.ModelState, r: jnp.ndarray):
        if self.cfg.arch_type == "ssm":
            state = ssm.rollback_ssm(state, r)
        elif self.cfg.arch_type == "hybrid" and "snaps" in state.layers:
            state = hybrid.rollback_hybrid(state, r)
        return kvc.rollback(state, r)

    # ---- training ------------------------------------------------------
    def train_logits(self, params, tokens, remat=True, **extras):
        """Dense/ssm/hybrid: logits. MoE: (logits, aux_loss)."""
        cfg = self.cfg
        kw: Dict[str, Any] = {}
        if cfg.arch_type == "audio":
            kw["enc_states"] = extras.get("enc_states")
        if cfg.arch_type == "vlm":
            if extras.get("mrope_positions") is not None:
                kw["mrope_positions"] = extras["mrope_positions"]
            if extras.get("input_embeds") is not None:
                kw["input_embeds"] = extras["input_embeds"]
        return self.mod.forward_train(params, cfg, tokens, remat=remat, **kw)

    def has_aux_loss(self) -> bool:
        return self.cfg.arch_type == "moe"

    # ---- convenience ---------------------------------------------------
    def extras_for(self, batch: int, key=None) -> Dict[str, Any]:
        """Concrete stub frontend inputs for smoke tests / serving."""
        cfg = self.cfg
        if cfg.arch_type == "audio":
            return {"enc_states": frontends.audio_encoder_stub(cfg, batch, key)}
        return {}

    def extras_specs(self, batch: int) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the dry-run."""
        cfg = self.cfg
        if cfg.arch_type == "audio":
            return {"enc_states": frontends.audio_encoder_spec(cfg, batch)}
        return {}
