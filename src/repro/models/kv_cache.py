"""ModelState: the paper's synchronized state abstraction (§4.4, Fig. 3).

A ModelState bundles the physical per-layer caches with the *logical* buffers
that make multi-level speculation consistent:

  token_buf  (B, S) int32  — cache_tokens in the paper
  pos_buf    (B, S) int32  — logical position stored in each physical slot
  mask       (B, S) bool   — cache_mask: logical validity (paper Eq. 8)
  length     (B,)   int32  — logical sequence length per row
  write_ptr  ()     int32  — shared physical append pointer

TPU adaptation of Eq. 9 (physical truncation): XLA needs static shapes, so
instead of slicing tensors we *rewind the shared write pointer* to the end of
the last physically-used slot that is still valid in any row.  This reclaims
exactly the common suffix (r_min) with zero data movement — strictly cheaper
than the paper's tensor copy.  Holes left by divergent per-row acceptance
stay masked; ``defragment`` (beyond-paper) compacts them when fragmentation
exceeds a threshold.

Paged variant (``PagedModelState``): the shared write pointer keys every
batch row to the SAME physical slots, so under slot-level continuous
batching each appended block consumes capacity for *every* slot — one
long-lived request plus admission churn burns the buffer at O(cycles) and
trips force-defragment (a full O(L·B·S·H·hd) cache copy) or a full state
rebuild on the hot path.  The paged state splits the physical cache into
fixed-size blocks drawn from a shared pool:

  write_ptr    (B,)   int32  — PER-ROW append cursor (row-local slot)
  block_table  (B, R) int32  — row-local block index -> pool block id (-1 free)
  num_blocks   (B,)   int32  — allocated blocks per row
  free_stack   (P,)   int32  — LIFO free list of pool block ids
  free_top     ()     int32  — number of free blocks (stack height)

Appends allocate blocks per row (only rows that write consume capacity),
``free_rows`` returns a retired row's blocks to the pool in O(1) (no
defragment, no masked-hole leak across slots), and rollback/``resolve_tree``
stay pure block-table + mask edits — the same zero-copy guarantees as the
pointer rewind.  Per-layer attention caches are pool-shaped
``(L, P·bs, Hkv, hd)``; rows address them through the block table
(``physical_slots`` / ``physical_view_index``).  Recurrent carries
(SSM/hybrid) keep the contiguous state + snapshot rings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ModelState:
    token_buf: jnp.ndarray          # (B, S) int32
    pos_buf: jnp.ndarray            # (B, S) int32
    mask: jnp.ndarray               # (B, S) bool
    length: jnp.ndarray             # (B,) int32
    write_ptr: jnp.ndarray          # () int32
    layers: Dict[str, Any]          # model-specific per-layer caches

    @property
    def batch(self) -> int:
        return self.token_buf.shape[0]

    @property
    def capacity(self) -> int:
        return self.token_buf.shape[1]


def make_state(batch: int, max_len: int, layers: Dict[str, Any]) -> ModelState:
    return ModelState(
        token_buf=jnp.zeros((batch, max_len), jnp.int32),
        pos_buf=jnp.zeros((batch, max_len), jnp.int32),
        mask=jnp.zeros((batch, max_len), jnp.bool_),
        length=jnp.zeros((batch,), jnp.int32),
        write_ptr=jnp.zeros((), jnp.int32),
        layers=layers,
    )


_BIG = jnp.int32(2 ** 30)


def _append_positions(state, valid, spec_depth):
    """Shared logical-position arithmetic for both state layouts.

    Returns (q_pos (B, T) with invalid -> far-future, adv (B,) length
    advance).  ``spec_depth`` semantics documented on ``append_tokens``."""
    if spec_depth is None:
        q_pos = (state.length[:, None]
                 + jnp.cumsum(valid.astype(jnp.int32), axis=1) - 1)
        adv = jnp.sum(valid, axis=1, dtype=jnp.int32)
    else:
        is_lin = (spec_depth < 0)[None, :]                       # (1, T)
        lin_valid = valid & is_lin
        lin_pos = (state.length[:, None]
                   + jnp.cumsum(lin_valid.astype(jnp.int32), axis=1) - 1)
        adv = jnp.sum(lin_valid, axis=1, dtype=jnp.int32)
        base = state.length + adv                                # (B,)
        spec_pos = base[:, None] + jnp.maximum(spec_depth, 0)[None, :]
        q_pos = jnp.where(is_lin, lin_pos, spec_pos)
    return jnp.where(valid, q_pos, _BIG), adv


# ---------------------------------------------------------------------------
# Logical append (all rows write the same physical slots [P, P+T))
# ---------------------------------------------------------------------------
def append_tokens(state, tokens: jnp.ndarray,
                  valid: Optional[jnp.ndarray] = None,
                  spec_depth: Optional[jnp.ndarray] = None):
    """Append T tokens per row; returns (new_state, q_positions (B,T), slot).

    Contiguous ``ModelState``: all rows write the shared physical slots
    [P, P+T) and ``slot`` is the scalar slot start.  ``PagedModelState``:
    each row writes only its own VALID entries at its per-row cursor
    (allocating pool blocks as needed) and ``slot`` is the (B, T) array of
    row-local slots (invalid entries -> far-future sentinel).

    ``valid`` (B, T) bool marks which appended entries are logically valid
    (used when a batch row has already finished but the batch step still runs).

    ``spec_depth`` (T,) int32 marks *speculative tree* entries: ``-1`` is a
    normal committed-stream token (linear cumsum position, advances
    ``length``), ``d >= 0`` is a tree node at depth ``d`` — its logical
    position is ``post-linear length + d`` (siblings share a position) and
    it does NOT advance ``length``; the block is later settled by
    ``resolve_tree`` (commit the winning path, mask dead branches).  With
    ``spec_depth=None`` the behaviour is bit-identical to the pre-tree code.
    """
    B, T = tokens.shape
    if valid is None:
        valid = jnp.ones((B, T), jnp.bool_)
    if isinstance(state, PagedModelState):
        return paged_append_tokens(state, tokens, valid, spec_depth)
    P = state.write_ptr
    q_pos, adv = _append_positions(state, valid, spec_depth)
    upd = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(buf, new, P, axis=1)
    new = dataclasses.replace(
        state,
        token_buf=upd(state.token_buf, tokens.astype(jnp.int32)),
        pos_buf=upd(state.pos_buf, q_pos.astype(jnp.int32)),
        mask=upd(state.mask, valid),
        length=state.length + adv,
        write_ptr=P + T,
    )
    return new, q_pos, P


# ---------------------------------------------------------------------------
# Rollback: Eq. 8 (logical) + Eq. 9 TPU analogue (pointer rewind)
# ---------------------------------------------------------------------------
def logical_rollback(state: ModelState, r: jnp.ndarray) -> ModelState:
    """Invalidate the last ``r[b]`` logically-valid entries of each row.

    Pure mask arithmetic — no data movement (paper step 1, Eq. 8)."""
    new_len = jnp.maximum(state.length - r.astype(jnp.int32), 0)
    keep = state.pos_buf < new_len[:, None]
    return dataclasses.replace(
        state, mask=state.mask & keep, length=new_len)


def physical_reclaim(state: ModelState) -> ModelState:
    """Rewind the shared write pointer past the common invalid suffix.

    TPU-native Eq. 9: reclaims the r_min common suffix without copying."""
    S = state.capacity
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    # highest still-valid physical slot across the whole batch
    last_valid = jnp.max(jnp.where(state.mask, slot_ids, -1))
    new_ptr = jnp.minimum(state.write_ptr, last_valid + 1)
    return dataclasses.replace(state, write_ptr=new_ptr.astype(jnp.int32))


def rollback(state, r: jnp.ndarray):
    """Full paper rollback: logical mask update then physical reclaim.

    Paged states rewind each row's OWN cursor (reclaiming even non-common
    suffixes) and return now-empty trailing blocks to the pool."""
    if isinstance(state, PagedModelState):
        return paged_rollback(state, r)
    return physical_reclaim(logical_rollback(state, r))


def resolve_tree(state, num_nodes: int, keep: jnp.ndarray,
                 add_len: jnp.ndarray,
                 active: Optional[jnp.ndarray] = None):
    """Settle a speculative tree block (the LAST ``num_nodes`` physical
    slots, appended with ``spec_depth``): keep the winning-path nodes, mask
    every dead branch, and advance each row's logical length by the number
    of kept nodes.

    Same machinery as logical rollback — pure mask arithmetic plus the
    write-pointer rewind, zero data movement.  Dead-branch holes inside the
    block stay masked and are reclaimed by ``defragment`` under capacity
    pressure, exactly like divergent-acceptance holes in linear mode.

    keep:    (B, N) bool — True for nodes on the row's committed path
    add_len: (B,) int32  — kept-path length (0 for inactive rows)
    active:  (B,) bool   — rows that actually appended a tree block this
             cycle.  Contiguous states can ignore it (inactive rows' block
             region holds freshly-written masked junk), but paged rows that
             sat out the cycle never advanced their cursor — their trailing
             slots hold COMMITTED data that must not be re-masked.
    """
    if isinstance(state, PagedModelState):
        assert active is not None, "paged resolve_tree needs the active mask"
        return paged_resolve_tree(state, num_nodes, keep, add_len, active)
    B, S = state.token_buf.shape
    start = state.write_ptr - num_nodes
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    in_block = (slot_ids >= start) & (slot_ids < state.write_ptr)
    keep_full = jnp.zeros((B, S), jnp.bool_)
    keep_full = jax.lax.dynamic_update_slice(keep_full, keep, (0, start))
    new = dataclasses.replace(
        state,
        mask=jnp.where(in_block, state.mask & keep_full, state.mask),
        length=state.length + add_len.astype(jnp.int32),
    )
    return physical_reclaim(new)


def path_keep_matrix(path_nodes: jnp.ndarray, keep_len: jnp.ndarray,
                     num_nodes: int, depth_levels: int) -> jnp.ndarray:
    """(B, D) winning-path node ids + (B,) consensus depth -> (B, N) bool
    keep matrix for ``resolve_tree`` (True for the first ``keep_len`` nodes
    along the path).  Pure index arithmetic, used in-program by both the
    per-op ResolveTreeProcessor and the fused cycle executor."""
    depth_ok = (jnp.arange(depth_levels, dtype=jnp.int32)[None, :]
                < keep_len[:, None])                            # (B, D)
    onehot = ((path_nodes[..., None]
               == jnp.arange(num_nodes, dtype=jnp.int32)[None, None, :])
              & depth_ok[..., None])                            # (B, D, N)
    return jnp.any(onehot, axis=1)                              # (B, N)


def free_rows(state, rows, layer_axes=None):
    """Retire a subset of batch rows so their slots can host new requests
    (slot-level continuous batching).

    Paged states return every block of the freed rows to the pool in O(1)
    (block-table + free-stack edits, no cache-tensor movement at all).

    Logical release is pure mask arithmetic: the rows' cache entries become
    dead (mask False, length 0) and are reclaimed by ``defragment`` under
    capacity pressure.  Per-position caches (named ``"seq"`` axis —
    attention KV and quant scales) need nothing more: masked slots are
    never attended, and rewriting them per retirement would be an
    O(L·B·S·H·hd) copy on the serving hot path.  Positionless recurrent
    carries (SSM / hybrid) WOULD leak the old request into the next
    occupant, so when ``layer_axes`` (the axes pytree from ``make_state``)
    is provided, every seq-less layer leaf with a named ``"batch"`` axis is
    zeroed along that axis for the freed rows.  Snapshot rings keep stale
    entries: they are keyed by physical slot, and a freshly admitted row
    only ever rolls back to slots written after its admission.
    """
    rows = jnp.asarray(rows, bool)                # (B,) True = free this row
    keep = ~rows
    if isinstance(state, PagedModelState):
        return paged_free_rows(state, rows, layer_axes)
    new = dataclasses.replace(
        state,
        mask=state.mask & keep[:, None],
        length=jnp.where(rows, 0, state.length).astype(jnp.int32),
    )
    if layer_axes is None:
        return new

    leaves, treedef = jax.tree.flatten(state.layers)
    ax_leaves = treedef.flatten_up_to(layer_axes)

    def wipe(x, ax):
        if not isinstance(ax, tuple) or "batch" not in ax or "seq" in ax:
            return x
        bi = ax.index("batch")
        shape = [1] * x.ndim
        shape[bi] = keep.shape[0]
        return x * keep.reshape(shape).astype(x.dtype)

    new_leaves = [wipe(x, ax) for x, ax in zip(leaves, ax_leaves)]
    return dataclasses.replace(
        new, layers=jax.tree.unflatten(treedef, new_leaves))


def fragmentation(state) -> jnp.ndarray:
    """Fraction of physically-used slots that are logically dead."""
    if isinstance(state, PagedModelState):
        return paged_fragmentation(state)
    S = state.capacity
    used = jnp.maximum(state.write_ptr, 1).astype(jnp.float32)
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    in_use = slot_ids < state.write_ptr
    dead = jnp.sum((~state.mask) & in_use, axis=1).astype(jnp.float32)
    return jnp.mean(dead) / used


def defragment(state: ModelState) -> ModelState:
    """Beyond-paper: compact every row's valid entries to the buffer front.

    Gathers each row's valid slots (stable order by logical position) and
    rewrites all buffers + every per-layer cache along the S axis.  O(S·cache)
    data movement — call only when ``fragmentation`` exceeds a threshold.
    """
    B, S = state.token_buf.shape
    big = jnp.int32(2**30)
    sort_key = jnp.where(state.mask, state.pos_buf, big)
    order = jnp.argsort(sort_key, axis=1)                       # (B, S)
    take = lambda buf: jnp.take_along_axis(buf, order, axis=1)
    n_valid = jnp.sum(state.mask, axis=1).astype(jnp.int32)
    new_mask = jnp.arange(S, dtype=jnp.int32)[None, :] < n_valid[:, None]

    def gather_cache(x):
        # per-layer caches are (L, B, S, ...): gather along axis=2
        if x.ndim >= 3 and x.shape[1] == B and x.shape[2] == S:
            idx = order.reshape((1, B, S) + (1,) * (x.ndim - 3))
            return jnp.take_along_axis(x, idx, axis=2)
        return x

    return dataclasses.replace(
        state,
        token_buf=take(state.token_buf),
        pos_buf=jnp.where(new_mask, take(state.pos_buf), 0),
        mask=new_mask,
        write_ptr=jnp.max(n_valid),
        layers=jax.tree.map(gather_cache, state.layers),
    )


# ---------------------------------------------------------------------------
# Attention KV cache helpers (stacked layers: (L, B, S, Hkv, hd))
# ---------------------------------------------------------------------------
def make_attn_cache(num_layers, batch, max_len, num_kv_heads, head_dim,
                    dtype, quant: bool = False):
    shape = (num_layers, batch, max_len, num_kv_heads, head_dim)
    if quant:
        # §Perf G2: int8 cache + per-(token, head) scales — halves the
        # dominant serving memory/traffic; dequant fuses into the dots
        sshape = (num_layers, batch, max_len, num_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.bfloat16),
                "v_scale": jnp.zeros(sshape, jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_cache_axes(prefix=(), quant: bool = False):
    ax = ("layers", "batch", "seq", "kv_heads", "head_dim")
    d = {"k": prefix + ax, "v": prefix + ax}
    if quant:
        sx = ("layers", "batch", "seq", "kv_heads")
        d["k_scale"] = prefix + sx
        d["v_scale"] = prefix + sx
    return d


def kv_quantize(x: jnp.ndarray):
    """(B, T, Hkv, hd) -> (int8 codes, (B, T, Hkv) bf16 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.bfloat16)


def kv_dequantize(codes: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (codes.astype(dtype) * scale[..., None].astype(dtype))


def write_kv(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
             k_new: jnp.ndarray, v_new: jnp.ndarray, slot_start):
    """Write (B,T,Hkv,hd) into a single layer's (B,S,Hkv,hd) cache views."""
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot_start, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot_start, axis=1)
    return ck, cv


# ---------------------------------------------------------------------------
# SSM snapshot buffers (rollback support for recurrent archs — DESIGN §5)
# ---------------------------------------------------------------------------
# Recurrent state has no per-position cache; rollback restores a snapshot.
# Snapshots are only materialized in the speculative serving path (small
# models); the dry-run decode step carries ``snaps=None``.
def snap_write(snaps: jnp.ndarray, current: jnp.ndarray, pos: jnp.ndarray):
    """snaps: (K, ...) ring buffer; store ``current`` at slot pos % K."""
    K = snaps.shape[0]
    return jax.lax.dynamic_update_index_in_dim(
        snaps, current, pos % K, axis=0)


def snap_read(snaps: jnp.ndarray, pos: jnp.ndarray):
    K = snaps.shape[0]
    return jax.lax.dynamic_index_in_dim(snaps, pos % K, axis=0, keepdims=False)


# ===========================================================================
# Paged KV cache: per-row block tables over a shared pool of fixed blocks
# ===========================================================================
PAGE_BLOCK = 32   # default tokens per KV block (TPU path wants >= 8)


def _ceil_div(a, b):
    return -(-a // b)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedModelState:
    """Paged analogue of ModelState (module docstring has the layout).

    The logical buffers (token/pos/mask/length) keep the exact (B, S)
    row-major addressing of the contiguous state — S is the per-row
    capacity ``blocks_per_row * block_size`` — so every mask consumer
    (``build_attention_mask``, overlays, verification) is unchanged.  Only
    the *physical* KV tensors move to the pool layout; rows translate
    row-local slots to pool slots through ``block_table``.
    """
    token_buf: jnp.ndarray          # (B, S) int32
    pos_buf: jnp.ndarray            # (B, S) int32
    mask: jnp.ndarray               # (B, S) bool
    length: jnp.ndarray             # (B,) int32
    write_ptr: jnp.ndarray          # (B,) int32 per-row append cursor
    block_table: jnp.ndarray        # (B, R) int32 pool block id or -1
    num_blocks: jnp.ndarray         # (B,) int32 allocated blocks per row
    free_stack: jnp.ndarray         # (P,) int32 LIFO of free pool block ids
    free_top: jnp.ndarray           # () int32 stack height (# free blocks)
    layers: Dict[str, Any]          # per-layer caches (attention: pool flat)
    block_size: int = dataclasses.field(
        metadata=dict(static=True), default=PAGE_BLOCK)

    @property
    def batch(self) -> int:
        return self.token_buf.shape[0]

    @property
    def capacity(self) -> int:
        """Per-row logical capacity (R * block_size)."""
        return self.token_buf.shape[1]

    @property
    def blocks_per_row(self) -> int:
        return self.block_table.shape[1]

    @property
    def pool_blocks(self) -> int:
        return self.free_stack.shape[0]


def make_paged_state(batch: int, max_len: int, layers: Dict[str, Any],
                     block_size: int = PAGE_BLOCK,
                     pool_blocks: Optional[int] = None) -> PagedModelState:
    """Per-row capacity rounds ``max_len`` up to whole blocks; the pool
    defaults to full provisioning (batch * blocks_per_row) so a session can
    never exhaust it while every row stays within its own budget —
    admission churn returns retired rows' blocks instead of burning new
    capacity."""
    R = _ceil_div(max_len, block_size)
    P = pool_blocks if pool_blocks is not None else batch * R
    S = R * block_size
    return PagedModelState(
        token_buf=jnp.zeros((batch, S), jnp.int32),
        pos_buf=jnp.zeros((batch, S), jnp.int32),
        mask=jnp.zeros((batch, S), jnp.bool_),
        length=jnp.zeros((batch,), jnp.int32),
        write_ptr=jnp.zeros((batch,), jnp.int32),
        block_table=jnp.full((batch, R), -1, jnp.int32),
        num_blocks=jnp.zeros((batch,), jnp.int32),
        free_stack=jnp.arange(P, dtype=jnp.int32),
        free_top=jnp.asarray(P, jnp.int32),
        layers=layers,
        block_size=int(block_size),
    )


def paged_state_axes(layer_axes: Dict[str, Any],
                     block_size: int) -> PagedModelState:
    """Logical-axis mirror of a PagedModelState (for sharding / free_rows)."""
    return PagedModelState(
        token_buf=("batch", "seq"), pos_buf=("batch", "seq"),
        mask=("batch", "seq"), length=("batch",), write_ptr=("batch",),
        block_table=("batch", None), num_blocks=("batch",),
        free_stack=(None,), free_top=(), layers=layer_axes,
        block_size=block_size)


def _alloc_blocks(state: PagedModelState, n_new_tokens: jnp.ndarray,
                  k_max: int):
    """Pop enough pool blocks for each row to hold ``n_new_tokens`` more
    entries past its cursor.  ``k_max`` is the static per-row bound on new
    blocks (ceil(T/bs) + 1).  Pure index arithmetic: pops only move
    ``free_top``; the stack array itself is untouched.

    Exhaustion (free_top underflow) leaves the rows' new table entries at
    -1 — writes to them are dropped, attention reads masked garbage for the
    affected row only.  The host-side capacity guard
    (``ChainRouter._ensure_capacity``) prevents this by block accounting.
    """
    B, R = state.block_table.shape
    bs = state.block_size
    high = state.write_ptr + n_new_tokens                       # (B,)
    need = jnp.maximum(_ceil_div(high, bs) - state.num_blocks, 0)
    offs = jnp.cumsum(need) - need                              # exclusive
    j = jnp.arange(k_max, dtype=jnp.int32)[None, :]             # (1, k_max)
    take = state.free_top - 1 - (offs[:, None] + j)             # (B, k_max)
    ok = (j < need[:, None]) & (take >= 0)
    pid = jnp.where(
        ok, state.free_stack[jnp.clip(take, 0, state.pool_blocks - 1)], -1)
    cols = jnp.where(ok, state.num_blocks[:, None] + j, R)      # R -> dropped
    bt = state.block_table.at[
        jnp.arange(B)[:, None], cols].set(pid, mode="drop")
    # account only the pops that SUCCEEDED (take >= 0 fails are a prefix
    # loss under exhaustion): inflating num_blocks with phantom blocks
    # would make the host-side block accounting pass while writes to the
    # -1 entries silently drop
    got = jnp.sum(ok, axis=1, dtype=jnp.int32)                  # (B,)
    return dataclasses.replace(
        state, block_table=bt, num_blocks=state.num_blocks + got,
        free_top=state.free_top - jnp.sum(got))


def _push_free_blocks(state: PagedModelState,
                      to_free: jnp.ndarray) -> PagedModelState:
    """Return the table entries flagged in ``to_free`` (B, R) to the pool:
    compact the freed ids, push them on the stack, null the table entries.
    O(B·R) int32 index work — never touches the cache tensors."""
    B, R = state.block_table.shape
    to_free = to_free & (state.block_table >= 0)
    flat_free = to_free.reshape(-1)
    ids = jnp.where(flat_free, state.block_table.reshape(-1), -1)
    order = jnp.argsort(jnp.where(flat_free, 0, 1), stable=True)
    ids_sorted = ids[order]                                    # freed first
    cnt = jnp.sum(flat_free, dtype=jnp.int32)
    pos = jnp.where(jnp.arange(B * R) < cnt,
                    state.free_top + jnp.arange(B * R),
                    state.pool_blocks)                          # OOB -> drop
    return dataclasses.replace(
        state,
        block_table=jnp.where(to_free, -1, state.block_table),
        free_stack=state.free_stack.at[pos].set(ids_sorted, mode="drop"),
        free_top=state.free_top + cnt)


def paged_append_tokens(state: PagedModelState, tokens: jnp.ndarray,
                        valid: jnp.ndarray,
                        spec_depth: Optional[jnp.ndarray] = None):
    """Per-row append: each row writes ONLY its valid entries, contiguously
    at its own cursor.  Rows with nothing valid (retired slots, masked
    no-op rows of a batched step) consume zero capacity — the structural
    fix for the shared-pointer churn blowup.  Returns
    (new_state, q_pos (B, T), slots (B, T) row-local, invalid -> sentinel).
    """
    B, T = tokens.shape
    q_pos, adv = _append_positions(state, valid, spec_depth)
    cnt = jnp.cumsum(valid.astype(jnp.int32), axis=1)           # (B, T)
    n_valid = cnt[:, -1]
    state = _alloc_blocks(state, n_valid,
                          k_max=_ceil_div(T, state.block_size) + 1)
    slots = jnp.where(valid, state.write_ptr[:, None] + cnt - 1, _BIG)
    bidx = jnp.arange(B)[:, None]
    new = dataclasses.replace(
        state,
        token_buf=state.token_buf.at[bidx, slots].set(
            tokens.astype(jnp.int32), mode="drop"),
        pos_buf=state.pos_buf.at[bidx, slots].set(
            q_pos.astype(jnp.int32), mode="drop"),
        mask=state.mask.at[bidx, slots].set(valid, mode="drop"),
        length=state.length + adv,
        write_ptr=state.write_ptr + n_valid,
    )
    return new, q_pos, slots


def physical_slots(state: PagedModelState,
                   slots: jnp.ndarray) -> jnp.ndarray:
    """Row-local slots (B, T) -> flat pool slot ids (block_table lookup).
    Invalid slots (the append sentinel) map OOB so scatter-writes drop."""
    bs = state.block_size
    R = state.blocks_per_row
    rb = slots // bs
    ok = (slots >= 0) & (rb < R)
    pid = jnp.take_along_axis(state.block_table,
                              jnp.clip(rb, 0, R - 1), axis=1)
    return jnp.where(ok & (pid >= 0), pid * bs + slots % bs, _BIG)


def physical_view_index(state: PagedModelState) -> jnp.ndarray:
    """(B, S) flat pool slot id backing each row-local slot.  Unallocated
    blocks clamp to pool slot 0 — their logical mask is False, so attention
    never consumes the garbage."""
    S = state.capacity
    bs = state.block_size
    s = jnp.arange(S, dtype=jnp.int32)
    pid = state.block_table[:, s // bs]                         # (B, S)
    return jnp.maximum(pid, 0) * bs + (s % bs)[None, :]


def tree_region_cols(state: PagedModelState,
                     num_region: int,
                     appended: jnp.ndarray) -> jnp.ndarray:
    """Row-local slots of the speculative tree region — the last
    ``num_region`` entries each appending row wrote (a draft level's region
    spans slots written by the cycle's EARLIER level appends, so it must be
    derived from the post-append cursor, not from this append's slots).
    Rows that appended nothing get the far-future sentinel (overlay drops
    them)."""
    cols = (state.write_ptr[:, None] - num_region
            + jnp.arange(num_region, dtype=jnp.int32)[None, :])
    return jnp.where(jnp.asarray(appended, bool)[:, None], cols, _BIG)


def paged_scatter(cache_flat: jnp.ndarray, new: jnp.ndarray,
                  phys: jnp.ndarray) -> jnp.ndarray:
    """Write (B, T, ...) entries into a (P·bs, ...) pool cache at flat pool
    slots ``phys`` (B, T); sentinel slots are dropped."""
    flat = new.reshape((-1,) + new.shape[2:]).astype(cache_flat.dtype)
    return cache_flat.at[phys.reshape(-1)].set(flat, mode="drop")


def paged_gather(cache_flat: jnp.ndarray,
                 view_idx: jnp.ndarray) -> jnp.ndarray:
    """(P·bs, ...) pool cache -> (B, S, ...) per-row contiguous view."""
    return cache_flat[view_idx]


def paged_write_kv(cache_k, cache_v, k_new, v_new, phys):
    """Paged analogue of ``write_kv``: scatter (B,T,Hkv,hd) into the flat
    (P·bs,Hkv,hd) pool views of a single layer."""
    return paged_scatter(cache_k, k_new, phys), \
        paged_scatter(cache_v, v_new, phys)


def _paged_reclaim(state: PagedModelState) -> PagedModelState:
    """Per-row Eq. 9: rewind each row's OWN cursor past its invalid suffix
    and return now-empty trailing blocks to the pool.  Strictly stronger
    than the contiguous pointer rewind (which only reclaims the suffix
    common to ALL rows)."""
    S = state.capacity
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    last = jnp.max(jnp.where(state.mask, slot_ids, -1), axis=1)  # (B,)
    new_wp = jnp.minimum(state.write_ptr, last + 1)
    keep_b = _ceil_div(new_wp, state.block_size)                 # (B,)
    j = jnp.arange(state.blocks_per_row, dtype=jnp.int32)[None, :]
    to_free = (j >= keep_b[:, None]) & (j < state.num_blocks[:, None])
    state = dataclasses.replace(
        state, write_ptr=new_wp,
        num_blocks=jnp.minimum(state.num_blocks, keep_b))
    return _push_free_blocks(state, to_free)


def paged_rollback(state: PagedModelState, r: jnp.ndarray) -> PagedModelState:
    new_len = jnp.maximum(state.length - r.astype(jnp.int32), 0)
    keep = state.pos_buf < new_len[:, None]
    return _paged_reclaim(dataclasses.replace(
        state, mask=state.mask & keep, length=new_len))


def paged_resolve_tree(state: PagedModelState, num_nodes: int,
                       keep: jnp.ndarray, add_len: jnp.ndarray,
                       active: jnp.ndarray) -> PagedModelState:
    """Settle the tree block of each ACTIVE row — its last ``num_nodes``
    row-local slots.  Inactive rows never appended, so their trailing slots
    hold committed data and stay untouched (gated by ``active``)."""
    B, S = state.token_buf.shape
    active = jnp.asarray(active, bool)
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    wp = state.write_ptr[:, None]
    start = wp - num_nodes
    in_block = active[:, None] & (slot_ids >= start) & (slot_ids < wp)
    cols = jnp.where(active[:, None],
                     start + jnp.arange(num_nodes, dtype=jnp.int32)[None, :],
                     _BIG)
    keep_full = jnp.zeros((B, S), jnp.bool_).at[
        jnp.arange(B)[:, None], cols].set(keep, mode="drop")
    new = dataclasses.replace(
        state,
        mask=jnp.where(in_block, state.mask & keep_full, state.mask),
        length=state.length + add_len.astype(jnp.int32),
    )
    return _paged_reclaim(new)


def paged_free_rows(state: PagedModelState, rows: jnp.ndarray,
                    layer_axes=None) -> PagedModelState:
    """O(1) retirement: zero the row's logical buffers, rewind its cursor,
    and push ALL its blocks back on the free stack.  No cache-tensor data
    movement — the next occupant simply allocates fresh blocks.  (The
    recurrent-carry wipe of the contiguous path is moot here: paged states
    are attention-only; SSM/hybrid archs keep the contiguous layout.)"""
    rows = jnp.asarray(rows, bool)
    keep = ~rows
    j = jnp.arange(state.blocks_per_row, dtype=jnp.int32)[None, :]
    to_free = rows[:, None] & (j < state.num_blocks[:, None])
    state = dataclasses.replace(
        state,
        mask=state.mask & keep[:, None],
        length=jnp.where(rows, 0, state.length).astype(jnp.int32),
        write_ptr=jnp.where(rows, 0, state.write_ptr).astype(jnp.int32),
        num_blocks=jnp.where(rows, 0, state.num_blocks).astype(jnp.int32),
    )
    state = _push_free_blocks(state, to_free)
    if layer_axes is None:
        return state
    # pool-shaped attention caches have no batch axis; per-row leaves that
    # do (e.g. whisper cross-KV) get the same exact wipe as the contiguous
    # path so a freed row never leaks into its next occupant
    leaves, treedef = jax.tree.flatten(state.layers)
    ax_leaves = treedef.flatten_up_to(layer_axes)

    def wipe(x, ax):
        if not isinstance(ax, tuple) or "batch" not in ax or "seq" in ax:
            return x
        bi = ax.index("batch")
        shape = [1] * x.ndim
        shape[bi] = keep.shape[0]
        return x * keep.reshape(shape).astype(x.dtype)

    new_leaves = [wipe(x, ax) for x, ax in zip(leaves, ax_leaves)]
    return dataclasses.replace(
        state, layers=jax.tree.unflatten(treedef, new_leaves))


def paged_fragmentation(state: PagedModelState) -> jnp.ndarray:
    """Dead fraction of in-use slots (within-row tree holes only — paged
    rows can never leak holes into each other)."""
    S = state.capacity
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    in_use = slot_ids < state.write_ptr[:, None]
    used = jnp.maximum(jnp.sum(in_use), 1).astype(jnp.float32)
    dead = jnp.sum((~state.mask) & in_use).astype(jnp.float32)
    return dead / used


def blocks_in_use(state: PagedModelState) -> jnp.ndarray:
    return jnp.asarray(state.pool_blocks, jnp.int32) - state.free_top


def make_paged_attn_cache(num_layers, pool_blocks, block_size, num_kv_heads,
                          head_dim, dtype, quant: bool = False):
    """Pool-shaped attention cache: flat (L, P·bs, Hkv, hd) — rows address
    it through the block table (``physical_slots``/``physical_view_index``);
    the Pallas paged kernel views it as (P, bs, Hkv, hd) blocks."""
    shape = (num_layers, pool_blocks * block_size, num_kv_heads, head_dim)
    if quant:
        sshape = shape[:-1]
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.bfloat16),
                "v_scale": jnp.zeros(sshape, jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_attn_cache_axes(quant: bool = False):
    ax = ("layers", "kv_pool", "kv_heads", "head_dim")
    d = {"k": ax, "v": ax}
    if quant:
        sx = ("layers", "kv_pool", "kv_heads")
        d["k_scale"] = sx
        d["v_scale"] = sx
    return d
