"""ModelState: the paper's synchronized state abstraction (§4.4, Fig. 3).

A ModelState bundles the physical per-layer caches with the *logical* buffers
that make multi-level speculation consistent:

  token_buf  (B, S) int32  — cache_tokens in the paper
  pos_buf    (B, S) int32  — logical position stored in each physical slot
  mask       (B, S) bool   — cache_mask: logical validity (paper Eq. 8)
  length     (B,)   int32  — logical sequence length per row
  write_ptr  ()     int32  — shared physical append pointer

TPU adaptation of Eq. 9 (physical truncation): XLA needs static shapes, so
instead of slicing tensors we *rewind the shared write pointer* to the end of
the last physically-used slot that is still valid in any row.  This reclaims
exactly the common suffix (r_min) with zero data movement — strictly cheaper
than the paper's tensor copy.  Holes left by divergent per-row acceptance
stay masked; ``defragment`` (beyond-paper) compacts them when fragmentation
exceeds a threshold.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ModelState:
    token_buf: jnp.ndarray          # (B, S) int32
    pos_buf: jnp.ndarray            # (B, S) int32
    mask: jnp.ndarray               # (B, S) bool
    length: jnp.ndarray             # (B,) int32
    write_ptr: jnp.ndarray          # () int32
    layers: Dict[str, Any]          # model-specific per-layer caches

    @property
    def batch(self) -> int:
        return self.token_buf.shape[0]

    @property
    def capacity(self) -> int:
        return self.token_buf.shape[1]


def make_state(batch: int, max_len: int, layers: Dict[str, Any]) -> ModelState:
    return ModelState(
        token_buf=jnp.zeros((batch, max_len), jnp.int32),
        pos_buf=jnp.zeros((batch, max_len), jnp.int32),
        mask=jnp.zeros((batch, max_len), jnp.bool_),
        length=jnp.zeros((batch,), jnp.int32),
        write_ptr=jnp.zeros((), jnp.int32),
        layers=layers,
    )


# ---------------------------------------------------------------------------
# Logical append (all rows write the same physical slots [P, P+T))
# ---------------------------------------------------------------------------
def append_tokens(state: ModelState, tokens: jnp.ndarray,
                  valid: Optional[jnp.ndarray] = None,
                  spec_depth: Optional[jnp.ndarray] = None):
    """Append T tokens per row at shared physical slots; returns
    (new_state, q_positions (B,T), slot_start ()).

    ``valid`` (B, T) bool marks which appended entries are logically valid
    (used when a batch row has already finished but the batch step still runs).

    ``spec_depth`` (T,) int32 marks *speculative tree* entries: ``-1`` is a
    normal committed-stream token (linear cumsum position, advances
    ``length``), ``d >= 0`` is a tree node at depth ``d`` — its logical
    position is ``post-linear length + d`` (siblings share a position) and
    it does NOT advance ``length``; the block is later settled by
    ``resolve_tree`` (commit the winning path, mask dead branches).  With
    ``spec_depth=None`` the behaviour is bit-identical to the pre-tree code.
    """
    B, T = tokens.shape
    P = state.write_ptr
    if valid is None:
        valid = jnp.ones((B, T), jnp.bool_)
    if spec_depth is None:
        q_pos = (state.length[:, None]
                 + jnp.cumsum(valid.astype(jnp.int32), axis=1) - 1)
        adv = jnp.sum(valid, axis=1, dtype=jnp.int32)
    else:
        is_lin = (spec_depth < 0)[None, :]                       # (1, T)
        lin_valid = valid & is_lin
        lin_pos = (state.length[:, None]
                   + jnp.cumsum(lin_valid.astype(jnp.int32), axis=1) - 1)
        adv = jnp.sum(lin_valid, axis=1, dtype=jnp.int32)
        base = state.length + adv                                # (B,)
        spec_pos = base[:, None] + jnp.maximum(spec_depth, 0)[None, :]
        q_pos = jnp.where(is_lin, lin_pos, spec_pos)
    q_pos = jnp.where(valid, q_pos, jnp.int32(2**30))  # invalid -> far future
    upd = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(buf, new, P, axis=1)
    new = dataclasses.replace(
        state,
        token_buf=upd(state.token_buf, tokens.astype(jnp.int32)),
        pos_buf=upd(state.pos_buf, q_pos.astype(jnp.int32)),
        mask=upd(state.mask, valid),
        length=state.length + adv,
        write_ptr=P + T,
    )
    return new, q_pos, P


# ---------------------------------------------------------------------------
# Rollback: Eq. 8 (logical) + Eq. 9 TPU analogue (pointer rewind)
# ---------------------------------------------------------------------------
def logical_rollback(state: ModelState, r: jnp.ndarray) -> ModelState:
    """Invalidate the last ``r[b]`` logically-valid entries of each row.

    Pure mask arithmetic — no data movement (paper step 1, Eq. 8)."""
    new_len = jnp.maximum(state.length - r.astype(jnp.int32), 0)
    keep = state.pos_buf < new_len[:, None]
    return dataclasses.replace(
        state, mask=state.mask & keep, length=new_len)


def physical_reclaim(state: ModelState) -> ModelState:
    """Rewind the shared write pointer past the common invalid suffix.

    TPU-native Eq. 9: reclaims the r_min common suffix without copying."""
    S = state.capacity
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    # highest still-valid physical slot across the whole batch
    last_valid = jnp.max(jnp.where(state.mask, slot_ids, -1))
    new_ptr = jnp.minimum(state.write_ptr, last_valid + 1)
    return dataclasses.replace(state, write_ptr=new_ptr.astype(jnp.int32))


def rollback(state: ModelState, r: jnp.ndarray) -> ModelState:
    """Full paper rollback: logical mask update then physical reclaim."""
    return physical_reclaim(logical_rollback(state, r))


def resolve_tree(state: ModelState, num_nodes: int, keep: jnp.ndarray,
                 add_len: jnp.ndarray) -> ModelState:
    """Settle a speculative tree block (the LAST ``num_nodes`` physical
    slots, appended with ``spec_depth``): keep the winning-path nodes, mask
    every dead branch, and advance each row's logical length by the number
    of kept nodes.

    Same machinery as logical rollback — pure mask arithmetic plus the
    write-pointer rewind, zero data movement.  Dead-branch holes inside the
    block stay masked and are reclaimed by ``defragment`` under capacity
    pressure, exactly like divergent-acceptance holes in linear mode.

    keep:    (B, N) bool — True for nodes on the row's committed path
    add_len: (B,) int32  — kept-path length (0 for inactive rows)
    """
    B, S = state.token_buf.shape
    start = state.write_ptr - num_nodes
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    in_block = (slot_ids >= start) & (slot_ids < state.write_ptr)
    keep_full = jnp.zeros((B, S), jnp.bool_)
    keep_full = jax.lax.dynamic_update_slice(keep_full, keep, (0, start))
    new = dataclasses.replace(
        state,
        mask=jnp.where(in_block, state.mask & keep_full, state.mask),
        length=state.length + add_len.astype(jnp.int32),
    )
    return physical_reclaim(new)


def free_rows(state: ModelState, rows, layer_axes=None) -> ModelState:
    """Retire a subset of batch rows so their slots can host new requests
    (slot-level continuous batching).

    Logical release is pure mask arithmetic: the rows' cache entries become
    dead (mask False, length 0) and are reclaimed by ``defragment`` under
    capacity pressure.  Per-position caches (named ``"seq"`` axis —
    attention KV and quant scales) need nothing more: masked slots are
    never attended, and rewriting them per retirement would be an
    O(L·B·S·H·hd) copy on the serving hot path.  Positionless recurrent
    carries (SSM / hybrid) WOULD leak the old request into the next
    occupant, so when ``layer_axes`` (the axes pytree from ``make_state``)
    is provided, every seq-less layer leaf with a named ``"batch"`` axis is
    zeroed along that axis for the freed rows.  Snapshot rings keep stale
    entries: they are keyed by physical slot, and a freshly admitted row
    only ever rolls back to slots written after its admission.
    """
    rows = jnp.asarray(rows, bool)                # (B,) True = free this row
    keep = ~rows
    new = dataclasses.replace(
        state,
        mask=state.mask & keep[:, None],
        length=jnp.where(rows, 0, state.length).astype(jnp.int32),
    )
    if layer_axes is None:
        return new

    leaves, treedef = jax.tree.flatten(state.layers)
    ax_leaves = treedef.flatten_up_to(layer_axes)

    def wipe(x, ax):
        if not isinstance(ax, tuple) or "batch" not in ax or "seq" in ax:
            return x
        bi = ax.index("batch")
        shape = [1] * x.ndim
        shape[bi] = keep.shape[0]
        return x * keep.reshape(shape).astype(x.dtype)

    new_leaves = [wipe(x, ax) for x, ax in zip(leaves, ax_leaves)]
    return dataclasses.replace(
        new, layers=jax.tree.unflatten(treedef, new_leaves))


def fragmentation(state: ModelState) -> jnp.ndarray:
    """Fraction of physically-used slots that are logically dead."""
    S = state.capacity
    used = jnp.maximum(state.write_ptr, 1).astype(jnp.float32)
    slot_ids = jnp.arange(S, dtype=jnp.int32)[None, :]
    in_use = slot_ids < state.write_ptr
    dead = jnp.sum((~state.mask) & in_use, axis=1).astype(jnp.float32)
    return jnp.mean(dead) / used


def defragment(state: ModelState) -> ModelState:
    """Beyond-paper: compact every row's valid entries to the buffer front.

    Gathers each row's valid slots (stable order by logical position) and
    rewrites all buffers + every per-layer cache along the S axis.  O(S·cache)
    data movement — call only when ``fragmentation`` exceeds a threshold.
    """
    B, S = state.token_buf.shape
    big = jnp.int32(2**30)
    sort_key = jnp.where(state.mask, state.pos_buf, big)
    order = jnp.argsort(sort_key, axis=1)                       # (B, S)
    take = lambda buf: jnp.take_along_axis(buf, order, axis=1)
    n_valid = jnp.sum(state.mask, axis=1).astype(jnp.int32)
    new_mask = jnp.arange(S, dtype=jnp.int32)[None, :] < n_valid[:, None]

    def gather_cache(x):
        # per-layer caches are (L, B, S, ...): gather along axis=2
        if x.ndim >= 3 and x.shape[1] == B and x.shape[2] == S:
            idx = order.reshape((1, B, S) + (1,) * (x.ndim - 3))
            return jnp.take_along_axis(x, idx, axis=2)
        return x

    return dataclasses.replace(
        state,
        token_buf=take(state.token_buf),
        pos_buf=jnp.where(new_mask, take(state.pos_buf), 0),
        mask=new_mask,
        write_ptr=jnp.max(n_valid),
        layers=jax.tree.map(gather_cache, state.layers),
    )


# ---------------------------------------------------------------------------
# Attention KV cache helpers (stacked layers: (L, B, S, Hkv, hd))
# ---------------------------------------------------------------------------
def make_attn_cache(num_layers, batch, max_len, num_kv_heads, head_dim,
                    dtype, quant: bool = False):
    shape = (num_layers, batch, max_len, num_kv_heads, head_dim)
    if quant:
        # §Perf G2: int8 cache + per-(token, head) scales — halves the
        # dominant serving memory/traffic; dequant fuses into the dots
        sshape = (num_layers, batch, max_len, num_kv_heads)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.bfloat16),
                "v_scale": jnp.zeros(sshape, jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_cache_axes(prefix=(), quant: bool = False):
    ax = ("layers", "batch", "seq", "kv_heads", "head_dim")
    d = {"k": prefix + ax, "v": prefix + ax}
    if quant:
        sx = ("layers", "batch", "seq", "kv_heads")
        d["k_scale"] = prefix + sx
        d["v_scale"] = prefix + sx
    return d


def kv_quantize(x: jnp.ndarray):
    """(B, T, Hkv, hd) -> (int8 codes, (B, T, Hkv) bf16 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.bfloat16)


def kv_dequantize(codes: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (codes.astype(dtype) * scale[..., None].astype(dtype))


def write_kv(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
             k_new: jnp.ndarray, v_new: jnp.ndarray, slot_start):
    """Write (B,T,Hkv,hd) into a single layer's (B,S,Hkv,hd) cache views."""
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot_start, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot_start, axis=1)
    return ck, cv


# ---------------------------------------------------------------------------
# SSM snapshot buffers (rollback support for recurrent archs — DESIGN §5)
# ---------------------------------------------------------------------------
# Recurrent state has no per-position cache; rollback restores a snapshot.
# Snapshots are only materialized in the speculative serving path (small
# models); the dry-run decode step carries ``snaps=None``.
def snap_write(snaps: jnp.ndarray, current: jnp.ndarray, pos: jnp.ndarray):
    """snaps: (K, ...) ring buffer; store ``current`` at slot pos % K."""
    K = snaps.shape[0]
    return jax.lax.dynamic_update_index_in_dim(
        snaps, current, pos % K, axis=0)


def snap_read(snaps: jnp.ndarray, pos: jnp.ndarray):
    K = snaps.shape[0]
    return jax.lax.dynamic_index_in_dim(snaps, pos % K, axis=0, keepdims=False)
