"""Mixture-of-Experts decoder (kimi-k2-1t, olmoe-1b-7b).

Token-choice top-k routing with per-expert capacity.  Dispatch uses the
"top-C tokens per expert" gather (an O(E·C·D) dense-gather formulation that
shards cleanly: tokens over the data axis, experts over the model axis, so
XLA inserts the all-to-all the paper's MoE baselines rely on).  Tokens beyond
capacity are dropped (standard capacity-factor semantics; the drop rate is
what the aux load-balance loss drives down).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from . import kv_cache as kvc
from . import layers as nn
from .config import ModelConfig
from . import transformer as tf


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------
def init_moe_ffn(key, cfg: ModelConfig):
    m = cfg.moe
    dt = cfg.dtype
    d, E, F = cfg.d_model, m.num_experts, m.d_expert
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(kr, (d, E)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(kg, (E, d, F)) * s).astype(dt),
        "w_up": (jax.random.normal(ku, (E, d, F)) * s).astype(dt),
        "w_down": (jax.random.normal(kd, (E, F, d)) / math.sqrt(F)).astype(dt),
    }
    if m.num_shared_experts > 0:
        ds = max(m.d_shared, m.d_expert) * m.num_shared_experts
        p["shared"], _ = nn.init_swiglu(ks, d, ds, dt)
    return p


def moe_ffn_axes(cfg: ModelConfig, prefix=("layers",)):
    ax = {
        "router": prefix + ("embed", "experts"),
        "w_gate": prefix + ("experts", "embed", "expert_mlp"),
        "w_up": prefix + ("experts", "embed", "expert_mlp"),
        "w_down": prefix + ("experts", "expert_mlp", "embed"),
    }
    if cfg.moe.num_shared_experts > 0:
        ax["shared"] = {
            "gate": {"w": prefix + ("embed", "mlp")},
            "up": {"w": prefix + ("embed", "mlp")},
            "down": {"w": prefix + ("mlp", "embed")},
        }
    return ax


def _maybe_constrain(x, spec):
    """with_sharding_constraint when a ('data','model') mesh is in context
    (dry-run / pod execution); no-op on the bare CPU test path."""
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        names = set(getattr(pm, "axis_names", ()) or ())
        am = jax.sharding.get_abstract_mesh()
        if am is not None and getattr(am, "axis_names", ()):
            names |= set(am.axis_names)
        if {"data", "model"} <= names:
            return jax.lax.with_sharding_constraint(x, spec)
    except (ImportError, AttributeError, TypeError):
        # probing unstable jax internals across versions; any of these
        # just means "no mesh in context" — fall through to the no-op
        pass
    return x


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    m = cfg.moe
    c = math.ceil(num_tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(1, min(c, num_tokens))


def moe_ffn(p, cfg: ModelConfig, x: jnp.ndarray):
    """x: (B, T, D) -> (y, aux_loss)."""
    m = cfg.moe
    B, T, D = x.shape
    N = B * T
    E, K = m.num_experts, m.top_k
    C = capacity(cfg, N)
    xf = x.reshape(N, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, K)               # (N, K)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)
    # dense (N, E) gate matrix — zero outside top-k
    gate = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32)
                   * top_vals[..., None], axis=1)             # (N, E)

    # per-expert top-C token selection among tokens that chose it
    score = jnp.where(gate > 0, probs, -1.0)                  # (N, E)
    sel_score, sel_idx = jax.lax.top_k(score.T, C)            # (E, C)
    sel_valid = sel_score > 0
    # §Perf K1 (EXPERIMENTS.md): dispatch payloads stay in the model dtype
    # (bf16) — the gathered (E,C,D) tensors cross chips; fp32 would double
    # the all-to-all/all-reduce bytes for zero quality gain (expert matmuls
    # accumulate in fp32 on the MXU regardless).
    # §Perf K3: pin the dispatch layout — experts over the model axis,
    # capacity over the data axis — so the token exchange lowers to the
    # minimal (E,C,D) all-to-all instead of dense all-reduces of gathered
    # fp32 intermediates (see EXPERIMENTS.md §Perf pair 2).
    x_e = jnp.take(xf.astype(x.dtype), sel_idx, axis=0)       # (E, C, D)
    gate_e = jnp.take_along_axis(gate.T, sel_idx, axis=1)     # (E, C)
    gate_e = jnp.where(sel_valid, gate_e, 0.0)

    h = jnp.einsum("ecd,edf->ecf", x_e, p["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x_e, p["w_up"],
                   preferred_element_type=jnp.float32)
    y_e = jnp.einsum("ecf,efd->ecd",
                     (jax.nn.silu(h) * u).astype(x.dtype), p["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    y_e = y_e * gate_e[..., None].astype(y_e.dtype)

    out = jnp.zeros((N, D), y_e.dtype).at[sel_idx.reshape(-1)].add(
        y_e.reshape(E * C, D), mode="drop")
    if m.num_shared_experts > 0:
        out = out + nn.swiglu(p["shared"], xf)

    # Switch-style load-balance loss
    f = jnp.mean(jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32),
                         axis=1), axis=0)                     # (E,)
    pbar = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pbar) * m.aux_loss_coef
    return out.reshape(B, T, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# §Perf K4 (EXPERIMENTS.md pair 2): explicit expert-parallel dispatch via
# shard_map.  XLA's auto-SPMD lowers the take()-based dispatch into dense
# all-reduces / full-activation all-gathers of fp32 intermediates; the
# hand-written exchange moves ONLY the selected top-k payload:
#   local routing -> bucket per expert-shard -> all_to_all("model")
#   -> local expert FFN -> all_to_all back -> local combine.
# Capacity semantics become per-(expert, data-shard) — the standard
# device-local capacity of real EP systems (Switch/GShard); with ample
# capacity factor the output equals moe_ffn exactly (tested).
# ---------------------------------------------------------------------------
def _ep_mesh():
    """The ('data','model') mesh in context, or None (CPU test path)."""
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and {"data", "model"} <= set(
                getattr(pm, "axis_names", ()) or ()):
            return pm
    except (ImportError, AttributeError, TypeError):
        # same unstable-internals probe as _maybe_constrain: failure
        # means "no usable mesh", which is the CPU test path
        pass
    return None


def moe_ffn_ep(p, cfg: ModelConfig, x: jnp.ndarray, mesh):
    """Expert-parallel MoE FFN under shard_map. x: (B, T, D)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, T, D = x.shape
    E, K = m.num_experts, m.top_k
    n_ep = mesh.shape["model"]               # expert shards
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in data_axes:
        n_dp *= mesh.shape[a]
    E_loc = E // n_ep
    # tokens sharded over ALL axes for dispatch — replicating them over
    # the model axis would make the all_to_all exchange identical copies
    # (16× redundant expert compute; measured and fixed, see EXPERIMENTS)
    N_loc = (B * T) // (n_dp * n_ep)
    C = max(1, min(N_loc, math.ceil(N_loc * K / E * m.capacity_factor)))

    def local(x_blk, router_w, w_gate, w_up, w_down, shared_p):
        # x_blk: (N_loc, D) — this device's token slice;
        # expert weights: this model shard's E_loc experts
        xf = x_blk.reshape(-1, D)
        logits = xf.astype(jnp.float32) @ router_w          # (N_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_vals, top_idx = jax.lax.top_k(probs, K)
        top_vals = top_vals / jnp.sum(top_vals, -1, keepdims=True)
        gate = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32)
                       * top_vals[..., None], axis=1)       # (N_loc, E)

        # bucket: for each GLOBAL expert, the top-C local tokens (by score)
        score = jnp.where(gate > 0, probs, -1.0)            # (N_loc, E)
        sel_score, sel_idx = jax.lax.top_k(score.T, C)      # (E, C)
        sel_valid = sel_score > 0
        payload = jnp.take(xf, sel_idx, axis=0)             # (E, C, D)
        payload = jnp.where(sel_valid[..., None], payload, 0.0)
        g_e = jnp.take_along_axis(gate.T, sel_idx, axis=1)  # (E, C)
        g_e = jnp.where(sel_valid, g_e, 0.0)

        # exchange over the model axis: send E/n_ep experts to each peer
        snd = payload.reshape(n_ep, E_loc, C, D)
        rcv = jax.lax.all_to_all(snd, "model", split_axis=0,
                                 concat_axis=0, tiled=False)
        # rcv: (n_dp_peers=n_ep groups, E_loc, C, D) — tokens from every
        # model-column peer destined to OUR experts
        xr = rcv.reshape(n_ep, E_loc, C, D)

        h = jnp.einsum("pecd,edf->pecf", xr, w_gate,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("pecd,edf->pecf", xr, w_up,
                       preferred_element_type=jnp.float32)
        yr = jnp.einsum("pecf,efd->pecd",
                        (jax.nn.silu(h) * u).astype(xr.dtype), w_down,
                        preferred_element_type=jnp.float32
                        ).astype(xr.dtype)
        # send results back to the owning token shards
        back = jax.lax.all_to_all(yr, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        y_e = back.reshape(E, C, D) * g_e[..., None].astype(back.dtype)
        out = jnp.zeros((N_loc, D), y_e.dtype).at[
            sel_idx.reshape(-1)].add(y_e.reshape(E * C, D), mode="drop")
        if m.num_shared_experts > 0:
            out = out + nn.swiglu(shared_p, xf)

        f = jnp.mean(jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32),
                             axis=1), axis=0)
        pbar = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f * pbar) * m.aux_loss_coef
        # aux is per-token-slice; mean over all slices
        aux = jax.lax.pmean(aux, data_axes + ("model",))
        return out, aux

    shared_p = p.get("shared", {k: {"w": jnp.zeros((1, 1), x.dtype)}
                                for k in ("gate", "up", "down")})
    shared_spec = jax.tree.map(lambda _: P(), shared_p)
    tok_axes = data_axes + ("model",)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(tok_axes, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  shared_spec),
        out_specs=(P(tok_axes, None), P()),
        check_rep=False)
    xf = x.reshape(B * T, D)
    out, aux = fn(xf, p["router"], p["w_gate"], p["w_up"], p["w_down"],
                  shared_p)
    return out.reshape(B, T, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Full model: attention blocks + MoE FFN, scanned over layers
# ---------------------------------------------------------------------------
def _init_layer_params(key, cfg: ModelConfig):
    dt = cfg.dtype
    k1, k2 = jax.random.split(key)
    p = {}
    p["ln1"], _ = nn.init_rmsnorm(cfg.d_model, dt)
    p["attn"], _ = nn.init_attention(k1, cfg, dt)
    p["ln2"], _ = nn.init_rmsnorm(cfg.d_model, dt)
    p["moe"] = init_moe_ffn(k2, cfg)
    return p


def _layer_axes(cfg: ModelConfig):
    L = ("layers",)
    return {
        "ln1": {"scale": L + ("embed",)},
        "ln2": {"scale": L + ("embed",)},
        "attn": {
            "q": {"w": L + ("embed", "heads")},
            "k": {"w": L + ("embed", "kv_heads")},
            "v": {"w": L + ("embed", "kv_heads")},
            "o": {"w": L + ("heads", "embed")},
        },
        "moe": moe_ffn_axes(cfg),
    }


def param_axes(cfg: ModelConfig):
    return {
        "embed": ("vocab", "embed"),
        "blocks": _layer_axes(cfg),
        "final_norm": {"scale": ("embed",)},
    }


def init(key, cfg: ModelConfig):
    dt = cfg.dtype
    k_emb, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dt),
        "blocks": jax.vmap(partial(_init_layer_params, cfg=cfg))(layer_keys),
        "final_norm": nn.init_rmsnorm(cfg.d_model, dt)[0],
    }
    return params, param_axes(cfg)


make_cache = tf.make_cache  # same attention KV cache as dense
make_paged_cache = tf.make_paged_cache


def moe_ffn_dispatch(p, cfg: ModelConfig, x: jnp.ndarray):
    """Route to the shard_map expert-parallel path when a ('data','model')
    mesh is in context and sizes divide; dense-gather path otherwise."""
    mesh = _ep_mesh()
    if mesh is not None:
        n_shards = mesh.shape["model"]
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                n_shards *= mesh.shape[a]
        if (cfg.moe.num_experts % mesh.shape["model"] == 0
                and (x.shape[0] * x.shape[1]) % n_shards == 0):
            return moe_ffn_ep(p, cfg, x, mesh)
    return moe_ffn(p, cfg, x)


def _moe_block(pl, cfg, x, *, k_cached, v_cached, mask, q_pos, theta,
               write_slot=None, paged_idx=None):
    h = nn.rmsnorm(pl["ln1"], x, cfg.rms_eps)
    q, k_new, v_new = nn.attention_qkv(pl["attn"], h, cfg)
    q = tf._rope_traced(q, q_pos, theta, cfg.head_dim)
    k_new = tf._rope_traced(k_new, q_pos, theta, cfg.head_dim)
    if k_cached is not None and paged_idx is not None:
        phys_new, view_idx = paged_idx
        ck, cv = kvc.paged_write_kv(k_cached, v_cached, k_new, v_new,
                                    phys_new)
        attn_out = nn.gqa_attention(q, kvc.paged_gather(ck, view_idx),
                                    kvc.paged_gather(cv, view_idx), mask)
        new_cache = (ck, cv)
    elif k_cached is not None:
        ck, cv = kvc.write_kv(k_cached, v_cached, k_new, v_new, write_slot)
        attn_out = nn.gqa_attention(q, ck, cv, mask)
        new_cache = (ck, cv)
    else:
        attn_out = nn.gqa_attention(q, k_new, v_new, mask)
        new_cache = None
    x = x + nn.attention_out(pl["attn"], attn_out)
    h2 = nn.rmsnorm(pl["ln2"], x, cfg.rms_eps)
    y, aux = moe_ffn_dispatch(pl["moe"], cfg, h2)
    return x + y, aux, new_cache


def forward_cached(params, cfg: ModelConfig, state: kvc.ModelState,
                   tokens, valid=None, logits_mode="all",
                   spec_depth=None, spec_attend=None, **_ignored):
    state, q_pos, slot = kvc.append_tokens(state, tokens, valid,
                                           spec_depth=spec_depth)
    paged = isinstance(state, kvc.PagedModelState)
    mask = nn.build_attention_mask(state.mask, state.pos_buf, q_pos, window=0)
    if spec_attend is not None:   # tree speculation: ancestor-mask override
        T = tokens.shape[1]
        spec_attend = jnp.asarray(spec_attend)
        if paged:
            appended = (valid.any(axis=1) if valid is not None
                        else jnp.ones((tokens.shape[0],), jnp.bool_))
            mask = nn.overlay_block_mask_at(
                mask, state.mask, spec_attend,
                kvc.tree_region_cols(state, spec_attend.shape[1],
                                     appended))
        else:
            mask = nn.overlay_block_mask(mask, state.mask, spec_attend,
                                         slot + T - spec_attend.shape[1])
    paged_idx = ((kvc.physical_slots(state, slot),
                  kvc.physical_view_index(state)) if paged else None)
    x = tf._embed(params, cfg, tokens)
    theta = jnp.float32(cfg.rope_theta)

    def body(x, s):
        x, _aux, (ck, cv) = _moe_block(
            s["pl"], cfg, x, k_cached=s["ck"], v_cached=s["cv"],
            mask=mask, q_pos=q_pos, theta=theta,
            write_slot=None if paged else slot, paged_idx=paged_idx)
        return x, {"k": ck, "v": cv}

    xs = {"pl": params["blocks"], "ck": state.layers["k"],
          "cv": state.layers["v"]}
    x, new_kv = jax.lax.scan(body, x, xs)
    state = dataclasses.replace(
        state, layers={**state.layers, "k": new_kv["k"], "v": new_kv["v"]})
    if logits_mode == "none":
        return None, state
    if logits_mode == "last":
        if valid is None:
            x_last = x[:, -1]
        else:
            idx = jnp.maximum(jnp.sum(valid, axis=1) - 1, 0)
            x_last = jnp.take_along_axis(
                x, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return tf._unembed(params, cfg, x_last), state
    return tf._unembed(params, cfg, x), state


def forward_train(params, cfg: ModelConfig, tokens, remat=True, **_ignored):
    """Returns (logits, aux_loss)."""
    B, S = tokens.shape
    x = tf._embed(params, cfg, tokens)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    ar = jnp.arange(S, dtype=jnp.int32)
    mask = jnp.broadcast_to(ar[None, :, None] >= ar[None, None, :], (B, S, S))
    theta = jnp.float32(cfg.rope_theta)

    def body(carry, s):
        x, aux_sum = carry
        x, aux, _ = _moe_block(s["pl"], cfg, x, k_cached=None, v_cached=None,
                               mask=mask, q_pos=pos, theta=theta)
        return (x, aux_sum + aux), None

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    (x, aux_total), _ = jax.lax.scan(
        fn, (x, jnp.zeros((), jnp.float32)), {"pl": params["blocks"]})
    return tf._unembed(params, cfg, x), aux_total
