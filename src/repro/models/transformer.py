"""Dense decoder transformer.

Covers: gemma3 (local:global SWA interleave, qk-norm, sandwich norms,
logit softcap), qwen1.5 (QKV bias), minitron, granite (MQA), whisper decoder
(cross-attention + learned positions), qwen2-vl (M-RoPE, patch embeds).

Layers are stacked along a leading L axis and executed with ``lax.scan``
(compile-time O(1) in depth — essential for 62-layer dry-runs on this host).
Per-layer heterogeneity (local vs global attention, rope theta) rides along
as scanned flag arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import kv_cache as kvc
from . import layers as nn
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer_params(key, cfg: ModelConfig):
    dt = cfg.dtype
    ks = jax.random.split(key, 8)
    p = {}
    p["ln1"], _ = nn.init_rmsnorm(cfg.d_model, dt)
    p["attn"], _ = nn.init_attention(ks[0], cfg, dt)
    p["ln2"], _ = nn.init_rmsnorm(cfg.d_model, dt)
    p["mlp"], _ = nn.init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dt)
    if cfg.sandwich_norm:
        p["post_attn_ln"], _ = nn.init_rmsnorm(cfg.d_model, dt)
        p["post_mlp_ln"], _ = nn.init_rmsnorm(cfg.d_model, dt)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((cfg.head_dim,), dt)}
        p["k_norm"] = {"scale": jnp.ones((cfg.head_dim,), dt)}
    if cfg.encdec is not None:
        p["ln_cross"], _ = nn.init_rmsnorm(cfg.d_model, dt)
        p["cross"], _ = nn.init_attention(
            ks[2], cfg, dt, kv_input_dim=cfg.encdec.d_encoder)
    return p


def _layer_axes(cfg: ModelConfig):
    L = ("layers",)
    ax: Dict[str, Any] = {
        "ln1": {"scale": L + ("embed",)},
        "ln2": {"scale": L + ("embed",)},
        "attn": {
            "q": {"w": L + ("embed", "heads")},
            "k": {"w": L + ("embed", "kv_heads")},
            "v": {"w": L + ("embed", "kv_heads")},
            "o": {"w": L + ("heads", "embed")},
        },
        "mlp": {
            "gate": {"w": L + ("embed", "mlp")},
            "up": {"w": L + ("embed", "mlp")},
            "down": {"w": L + ("mlp", "embed")},
        },
    }
    if cfg.qkv_bias:
        for n in ("q", "k", "v"):
            tgt = "heads" if n == "q" else "kv_heads"
            ax["attn"][n]["b"] = L + (tgt,)
    if cfg.sandwich_norm:
        ax["post_attn_ln"] = {"scale": L + ("embed",)}
        ax["post_mlp_ln"] = {"scale": L + ("embed",)}
    if cfg.qk_norm:
        ax["q_norm"] = {"scale": L + ("head_dim",)}
        ax["k_norm"] = {"scale": L + ("head_dim",)}
    if cfg.encdec is not None:
        ax["ln_cross"] = {"scale": L + ("embed",)}
        ax["cross"] = {
            "q": {"w": L + ("embed", "heads")},
            "k": {"w": L + ("enc_embed", "kv_heads")},
            "v": {"w": L + ("enc_embed", "kv_heads")},
            "o": {"w": L + ("heads", "embed")},
        }
    return ax


def param_axes(cfg: ModelConfig):
    axes: Dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "blocks": _layer_axes(cfg),
        "final_norm": {"scale": ("embed",)},
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = {"w": ("embed", "vocab")}
    if cfg.learned_positions:
        axes["pos_embed"] = ("seq", "embed")
    return axes


def init(key, cfg: ModelConfig):
    dt = cfg.dtype
    k_emb, k_layers, k_head, k_pos = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dt),
        "blocks": jax.vmap(partial(_init_layer_params, cfg=cfg))(layer_keys),
        "final_norm": nn.init_rmsnorm(cfg.d_model, dt)[0],
    }
    if not cfg.tie_embeddings:
        params["lm_head"], _ = nn.init_linear(
            k_head, cfg.d_model, cfg.vocab_size, "embed", "vocab", dt)
    if cfg.learned_positions:
        params["pos_embed"] = (jax.random.normal(
            k_pos, (cfg.max_position, cfg.d_model)) * 0.02).astype(dt)
    return params, param_axes(cfg)


def layer_flags(cfg: ModelConfig):
    """Per-layer scanned metadata: (is_global (L,), rope_theta (L,))."""
    L = cfg.num_layers
    is_global = jnp.array(
        [cfg.is_global_layer(i) for i in range(L)], jnp.bool_)
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    thetas = jnp.where(is_global, theta_g, cfg.rope_theta).astype(jnp.float32)
    return is_global, thetas


# ---------------------------------------------------------------------------
# Shared block computation
# ---------------------------------------------------------------------------
def _embed(params, cfg: ModelConfig, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _unembed(params, cfg: ModelConfig, x):
    x = nn.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = nn.linear(params["lm_head"], x)
    return nn.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def _qk_normed(pl, cfg, q, k):
    if cfg.qk_norm:
        q = nn.rmsnorm(pl["q_norm"], q, cfg.rms_eps)
        k = nn.rmsnorm(pl["k_norm"], k, cfg.rms_eps)
    return q, k


def _block(pl, cfg: ModelConfig, x, *, k_cached, v_cached, mask,
           q_pos3, theta, cross_kv=None, write_slot=None, kv_scales=None,
           paged_idx=None):
    """One transformer block.

    k_cached/v_cached: (B, S, Hkv, hd) — full physical cache view for this
    layer (already containing the new tokens' K/V written by caller? No —
    we compute and write here when write_slot is given; for trainer mode
    k_cached is None and attention is over the block itself).
    kv_scales: (k_scale, v_scale) (B, S, Hkv) when cfg.kv_quant.
    paged_idx: (phys_new (B, T), view_idx (B, S)) when the state is paged —
    k_cached/v_cached are then flat pool tensors (P·bs, Hkv, hd): new K/V
    scatter to ``phys_new`` and attention consumes the per-row gathered
    view.  Materializing the gather is the CPU/jnp staging path (same
    convention as every kernel in this repo: the jnp forward is the
    oracle-checked reference); the TPU serving path replaces it with
    ``ops.paged_decode_attention``, whose scalar-prefetched block table
    performs the identical gather block-by-block inside the kernel
    pipeline with no materialized view.
    """
    h = nn.rmsnorm(pl["ln1"], x, cfg.rms_eps)
    q, k_new, v_new = nn.attention_qkv(pl["attn"], h, cfg)
    q, k_new = _qk_normed(pl, cfg, q, k_new)
    if cfg.vlm is not None:
        q = nn.apply_mrope(q, q_pos3, cfg.vlm.mrope_sections, theta)
        k_new = nn.apply_mrope(k_new, q_pos3, cfg.vlm.mrope_sections, theta)
    else:
        qp = q_pos3[..., 0]
        q = _rope_traced(q, qp, theta, cfg.head_dim)
        k_new = _rope_traced(k_new, qp, theta, cfg.head_dim)

    if k_cached is not None and paged_idx is not None:
        phys_new, view_idx = paged_idx
        if cfg.kv_quant:
            kq, ksc = kvc.kv_quantize(k_new)
            vq, vsc = kvc.kv_quantize(v_new)
            ck, cv = kvc.paged_write_kv(k_cached, v_cached, kq, vq, phys_new)
            cks = kvc.paged_scatter(kv_scales[0], ksc, phys_new)
            cvs = kvc.paged_scatter(kv_scales[1], vsc, phys_new)
            attn_out = nn.gqa_attention_quant(
                q, kvc.paged_gather(ck, view_idx),
                kvc.paged_gather(cks, view_idx),
                kvc.paged_gather(cv, view_idx),
                kvc.paged_gather(cvs, view_idx), mask, cfg.attn_softcap)
            new_cache = (ck, cv, cks, cvs)
        else:
            ck, cv = kvc.paged_write_kv(k_cached, v_cached, k_new, v_new,
                                        phys_new)
            attn_out = nn.gqa_attention(q, kvc.paged_gather(ck, view_idx),
                                        kvc.paged_gather(cv, view_idx),
                                        mask, cfg.attn_softcap)
            new_cache = (ck, cv)
    elif k_cached is not None:
        if cfg.kv_quant:
            kq, ksc = kvc.kv_quantize(k_new)
            vq, vsc = kvc.kv_quantize(v_new)
            ck, cv = kvc.write_kv(k_cached, v_cached, kq, vq, write_slot)
            upd = lambda buf, new: jax.lax.dynamic_update_slice_in_dim(
                buf, new.astype(buf.dtype), write_slot, axis=1)
            cks = upd(kv_scales[0], ksc)
            cvs = upd(kv_scales[1], vsc)
            attn_out = nn.gqa_attention_quant(
                q, ck, cks, cv, cvs, mask, cfg.attn_softcap)
            new_cache = (ck, cv, cks, cvs)
        else:
            ck, cv = kvc.write_kv(k_cached, v_cached, k_new, v_new,
                                  write_slot)
            attn_out = nn.gqa_attention(q, ck, cv, mask, cfg.attn_softcap)
            new_cache = (ck, cv)
    else:
        attn_out = nn.gqa_attention(q, k_new, v_new, mask, cfg.attn_softcap)
        new_cache = None
    a = nn.attention_out(pl["attn"], attn_out)
    if cfg.sandwich_norm:
        a = nn.rmsnorm(pl["post_attn_ln"], a, cfg.rms_eps)
    x = x + a

    if cross_kv is not None:  # whisper decoder cross-attention
        hc = nn.rmsnorm(pl["ln_cross"], x, cfg.rms_eps)
        B, T, _ = hc.shape
        qc = nn.linear(pl["cross"]["q"], hc).reshape(
            B, T, cfg.num_heads, cfg.head_dim)
        ck_, cv_ = cross_kv  # (B, S_enc, Hkv, hd) — precomputed at prefill
        cm = jnp.ones((B, T, ck_.shape[1]), jnp.bool_)
        co = nn.gqa_attention(qc, ck_, cv_, cm)
        x = x + nn.attention_out(pl["cross"], co)

    h2 = nn.rmsnorm(pl["ln2"], x, cfg.rms_eps)
    m = nn.swiglu(pl["mlp"], h2)
    if cfg.sandwich_norm:
        m = nn.rmsnorm(pl["post_mlp_ln"], m, cfg.rms_eps)
    return x + m, new_cache


def _rope_traced(x, positions, theta, head_dim):
    """RoPE with a *traced* theta (per-layer scanned scalar)."""
    half = head_dim // 2
    exponent = jnp.arange(half, dtype=jnp.float32) / half
    freqs = 1.0 / (theta ** exponent)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Cached forward (prefill + decode): scan over layers
# ---------------------------------------------------------------------------
def make_cache(cfg: ModelConfig, batch: int, max_len: int):
    layers = kvc.make_attn_cache(cfg.num_layers, batch, max_len,
                                 cfg.num_kv_heads, cfg.head_dim, cfg.dtype,
                                 quant=cfg.kv_quant)
    axes = kvc.attn_cache_axes(quant=cfg.kv_quant)
    if cfg.encdec is not None:
        e = cfg.encdec
        shape = (cfg.num_layers, batch, e.num_encoder_positions,
                 cfg.num_kv_heads, cfg.head_dim)
        layers["cross_k"] = jnp.zeros(shape, cfg.dtype)
        layers["cross_v"] = jnp.zeros(shape, cfg.dtype)
        axes["cross_k"] = ("layers", "batch", "enc_seq", "kv_heads", "head_dim")
        axes["cross_v"] = ("layers", "batch", "enc_seq", "kv_heads", "head_dim")
    return layers, axes


def make_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     block_size: int = kvc.PAGE_BLOCK,
                     pool_blocks: int | None = None):
    """Pool-shaped attention KV for a paged state.  Cross-attention KV
    (whisper) stays per-row: the encoder context is fixed-length and never
    appended to, so paging it buys nothing."""
    R = kvc._ceil_div(max_len, block_size)
    P = pool_blocks if pool_blocks is not None else batch * R
    layers = kvc.make_paged_attn_cache(cfg.num_layers, P, block_size,
                                       cfg.num_kv_heads, cfg.head_dim,
                                       cfg.dtype, quant=cfg.kv_quant)
    axes = kvc.paged_attn_cache_axes(quant=cfg.kv_quant)
    if cfg.encdec is not None:
        e = cfg.encdec
        shape = (cfg.num_layers, batch, e.num_encoder_positions,
                 cfg.num_kv_heads, cfg.head_dim)
        layers["cross_k"] = jnp.zeros(shape, cfg.dtype)
        layers["cross_v"] = jnp.zeros(shape, cfg.dtype)
        axes["cross_k"] = ("layers", "batch", "enc_seq", "kv_heads", "head_dim")
        axes["cross_v"] = ("layers", "batch", "enc_seq", "kv_heads", "head_dim")
    return layers, axes


def precompute_cross_kv(params, cfg: ModelConfig, enc_states):
    """Whisper: compute per-layer cross K/V from encoder output once."""
    def one(pl):
        B, S, _ = enc_states.shape
        k = nn.linear(pl["cross"]["k"], enc_states).reshape(
            B, S, cfg.num_kv_heads, cfg.head_dim)
        v = nn.linear(pl["cross"]["v"], enc_states).reshape(
            B, S, cfg.num_kv_heads, cfg.head_dim)
        return k, v
    return jax.vmap(one)(params["blocks"])  # over stacked L axis


def forward_cached(params, cfg: ModelConfig, state: kvc.ModelState,
                   tokens: jnp.ndarray,
                   valid: Optional[jnp.ndarray] = None,
                   input_embeds: Optional[jnp.ndarray] = None,
                   mrope_positions: Optional[jnp.ndarray] = None,
                   logits_mode: str = "all",
                   spec_depth: Optional[jnp.ndarray] = None,
                   spec_attend: Optional[jnp.ndarray] = None):
    """Append T tokens, run all layers, return (logits, new_state).

    logits_mode: 'all' -> (B,T,V); 'last' -> (B,V) at each row's last valid.

    Tree-structured speculation: ``spec_depth`` (T,) marks tree entries of
    the block (-1 = committed-stream token; d >= 0 = tree node at depth d,
    positioned at post-linear length + d) and ``spec_attend`` (T, R) is the
    static ancestor mask overriding the attention columns of the cycle's
    tree region — the LAST R physical slots after this append (earlier
    draft levels of the same cycle sit contiguously before this block).
    The override also applies to sliding-window layers: tree depths are
    tiny relative to any real window, so ancestors are never out-of-window.
    """
    state, q_pos, slot = kvc.append_tokens(state, tokens, valid,
                                           spec_depth=spec_depth)
    B, T = tokens.shape
    paged = isinstance(state, kvc.PagedModelState)
    x = input_embeds if input_embeds is not None else _embed(params, cfg, tokens)
    if cfg.learned_positions:
        safe = jnp.clip(q_pos, 0, cfg.max_position - 1)
        x = x + params["pos_embed"][safe]

    kv_pos = state.pos_buf
    m_full = nn.build_attention_mask(state.mask, kv_pos, q_pos, window=0)
    m_win = (nn.build_attention_mask(state.mask, kv_pos, q_pos,
                                     window=cfg.sliding_window)
             if cfg.sliding_window > 0 else m_full)
    if spec_attend is not None:
        spec_attend = jnp.asarray(spec_attend)
        if paged:
            appended = (valid.any(axis=1) if valid is not None
                        else jnp.ones((B,), jnp.bool_))
            cols = kvc.tree_region_cols(state, spec_attend.shape[1],
                                        appended)
            m_full = nn.overlay_block_mask_at(m_full, state.mask,
                                              spec_attend, cols)
            if cfg.sliding_window > 0:
                m_win = nn.overlay_block_mask_at(m_win, state.mask,
                                                 spec_attend, cols)
        else:
            region_start = slot + T - spec_attend.shape[1]
            m_full = nn.overlay_block_mask(m_full, state.mask,
                                           spec_attend, region_start)
            if cfg.sliding_window > 0:
                m_win = nn.overlay_block_mask(m_win, state.mask,
                                              spec_attend, region_start)
    paged_idx = ((kvc.physical_slots(state, slot),
                  kvc.physical_view_index(state)) if paged else None)
    if mrope_positions is None:
        q_pos3 = jnp.repeat(q_pos[..., None], 3, axis=-1)
    else:
        q_pos3 = mrope_positions

    is_global, thetas = layer_flags(cfg)
    has_cross = cfg.encdec is not None
    xs = {"pl": params["blocks"], "ck": state.layers["k"],
          "cv": state.layers["v"], "g": is_global, "theta": thetas}
    if cfg.kv_quant:
        xs["cks"] = state.layers["k_scale"]
        xs["cvs"] = state.layers["v_scale"]
    if has_cross:
        xs["xk"] = state.layers["cross_k"]
        xs["xv"] = state.layers["cross_v"]

    def body(x, s):
        mask = jnp.where(s["g"], m_full, m_win) if cfg.sliding_window > 0 \
            else m_full
        cross = (s["xk"], s["xv"]) if has_cross else None
        scales = (s["cks"], s["cvs"]) if cfg.kv_quant else None
        x, caches = _block(
            s["pl"], cfg, x, k_cached=s["ck"], v_cached=s["cv"], mask=mask,
            q_pos3=q_pos3, theta=s["theta"], cross_kv=cross,
            write_slot=None if paged else slot, kv_scales=scales,
            paged_idx=paged_idx)
        out = {"k": caches[0], "v": caches[1]}
        if cfg.kv_quant:
            out["k_scale"], out["v_scale"] = caches[2], caches[3]
        return x, out

    x, new_kv = jax.lax.scan(body, x, xs)
    state = dataclasses.replace(state, layers={**state.layers, **new_kv})

    if logits_mode == "none":
        return None, state
    if logits_mode == "last":
        if valid is None:
            x_last = x[:, -1]
        else:
            idx = jnp.maximum(jnp.sum(valid, axis=1) - 1, 0)
            x_last = jnp.take_along_axis(
                x, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return _unembed(params, cfg, x_last), state
    return _unembed(params, cfg, x), state


# ---------------------------------------------------------------------------
# Trainer forward (no cache, full causal)
# ---------------------------------------------------------------------------
def forward_train(params, cfg: ModelConfig, tokens: jnp.ndarray,
                  input_embeds: Optional[jnp.ndarray] = None,
                  mrope_positions: Optional[jnp.ndarray] = None,
                  enc_states: Optional[jnp.ndarray] = None,
                  remat: bool = True):
    B, S = tokens.shape
    x = input_embeds if input_embeds is not None else _embed(params, cfg, tokens)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    if cfg.learned_positions:
        x = x + params["pos_embed"][pos]
    ar = jnp.arange(S, dtype=jnp.int32)
    causal = ar[None, :, None] >= ar[None, None, :]
    m_full = jnp.broadcast_to(causal, (B, S, S))
    if cfg.sliding_window > 0:
        m_win = m_full & (ar[None, None, :] > ar[None, :, None] - cfg.sliding_window)
    else:
        m_win = m_full
    q_pos3 = (jnp.repeat(pos[..., None], 3, axis=-1)
              if mrope_positions is None else mrope_positions)
    is_global, thetas = layer_flags(cfg)
    has_cross = cfg.encdec is not None
    cross_kv_all = (precompute_cross_kv(params, cfg, enc_states)
                    if has_cross else None)

    xs = {"pl": params["blocks"], "g": is_global, "theta": thetas}
    if has_cross:
        xs["xk"], xs["xv"] = cross_kv_all

    def body(x, s):
        mask = jnp.where(s["g"], m_full, m_win) if cfg.sliding_window > 0 \
            else m_full
        cross = (s["xk"], s["xv"]) if has_cross else None
        x, _ = _block(s["pl"], cfg, x, k_cached=None, v_cached=None,
                      mask=mask, q_pos3=q_pos3, theta=s["theta"],
                      cross_kv=cross)
        return x, None

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, _ = jax.lax.scan(fn, x, xs)
    return _unembed(params, cfg, x)
