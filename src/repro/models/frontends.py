"""Modality frontends — STUBS by assignment carve-out.

[audio] whisper-tiny: the mel-spectrogram + conv feature extractor is not
implemented; ``audio_encoder_stub`` yields precomputed frame embeddings of
the correct shape (B, 1500, d_encoder) that the decoder cross-attends to.

[vlm] qwen2-vl: the ViT/patch-merger is not implemented; ``vision_stub``
yields projected patch embeddings (B, n_patch, d_model) that are prepended
to the text embeddings, plus the M-RoPE (t, h, w) position grid for them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def audio_encoder_stub(cfg: ModelConfig, batch: int, key=None):
    e = cfg.encdec
    if key is None:
        return jnp.zeros((batch, e.num_encoder_positions, e.d_encoder),
                         cfg.dtype)
    return (jax.random.normal(
        key, (batch, e.num_encoder_positions, e.d_encoder)) * 0.02
    ).astype(cfg.dtype)


def audio_encoder_spec(cfg: ModelConfig, batch: int):
    e = cfg.encdec
    return jax.ShapeDtypeStruct(
        (batch, e.num_encoder_positions, e.d_encoder), cfg.dtype)


def vision_stub(cfg: ModelConfig, batch: int, key=None):
    v = cfg.vlm
    if key is None:
        return jnp.zeros((batch, v.num_patch_tokens, cfg.d_model), cfg.dtype)
    return (jax.random.normal(
        key, (batch, v.num_patch_tokens, cfg.d_model)) * 0.02
    ).astype(cfg.dtype)


def vision_spec(cfg: ModelConfig, batch: int):
    v = cfg.vlm
    return jax.ShapeDtypeStruct(
        (batch, v.num_patch_tokens, cfg.d_model), cfg.dtype)


def mrope_patch_positions(cfg: ModelConfig, batch: int):
    """(B, n_patch, 3) (t,h,w) grid for a square patch layout; dynamic
    resolution reduces to choosing the grid — square stub here."""
    v = cfg.vlm
    n = v.num_patch_tokens
    side = int(n ** 0.5)
    hh, ww = jnp.meshgrid(jnp.arange(side), jnp.arange(side), indexing="ij")
    grid = jnp.stack([jnp.zeros_like(hh), hh, ww], axis=-1).reshape(-1, 3)
    grid = grid[:n]
    return jnp.broadcast_to(grid[None], (batch, n, 3)).astype(jnp.int32)


def mrope_text_positions(start, length, batch):
    """Text tokens: all three streams share the scalar position."""
    pos = start[:, None] + jnp.arange(length, dtype=jnp.int32)[None, :]
    return jnp.repeat(pos[..., None], 3, axis=-1)
