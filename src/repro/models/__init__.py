from .config import (EncDecConfig, InputShape, INPUT_SHAPES, MoEConfig,
                     ModelConfig, SSMConfig, VLMConfig)
from .model import LanguageModel
