"""xLSTM (arXiv:2405.04517): mLSTM (matrix-memory) + sLSTM (scalar-memory)
blocks, interleaved in groups (xLSTM[k:1] style).

Rollback adaptation (DESIGN §5): recurrent models have no per-position KV
cache, so speculative rollback restores a *state snapshot*.  Every decode
step writes the post-token recurrent state into a small ring buffer
(``snaps``, K slots, K > max draft window); rollback gathers the per-row
snapshot at the accepted length.  Invalid (masked) tokens are processed as
no-ops per row so snapshots stay row-consistent.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import kv_cache as kvc
from . import layers as nn
from .config import ModelConfig
from . import transformer as tf

SNAP_SLOTS = 16  # > any draft window we use


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------
def _inner(cfg):
    return int(cfg.d_model * (cfg.ssm.mlstm_proj_factor if cfg.ssm else 2.0))


def init_mlstm_block(key, cfg: ModelConfig):
    dt = cfg.dtype
    d, NH = cfg.d_model, cfg.num_heads
    inner = _inner(cfg)
    dh = inner // NH
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    si = 1.0 / math.sqrt(inner)
    p = {
        "ln": nn.init_rmsnorm(d, dt)[0],
        "up": (jax.random.normal(ks[0], (d, 2 * inner)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (4, inner)) * 0.5).astype(dt),
        "wq": (jax.random.normal(ks[2], (inner, inner)) * si).astype(dt),
        "wk": (jax.random.normal(ks[3], (inner, inner)) * si).astype(dt),
        "wv": (jax.random.normal(ks[4], (inner, inner)) * si).astype(dt),
        "w_if": (jax.random.normal(ks[5], (inner, 2 * NH)) * si).astype(jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((NH,)), 3.0 + jnp.arange(NH) * 0.5]
                                ).astype(jnp.float32),
        "gn": jnp.ones((inner,), dt),
        "down": (jax.random.normal(ks[6], (inner, d)) * si).astype(dt),
    }
    return p


def mlstm_axes(prefix):
    return {
        "ln": {"scale": prefix + ("embed",)},
        "up": prefix + ("embed", "ssm_inner"),
        "conv_w": prefix + ("conv", "ssm_inner"),
        "wq": prefix + ("ssm_inner", "ssm_inner"),
        "wk": prefix + ("ssm_inner", "ssm_inner"),
        "wv": prefix + ("ssm_inner", "ssm_inner"),
        "w_if": prefix + ("ssm_inner", None),
        "b_if": prefix + (None,),
        "gn": prefix + ("ssm_inner",),
        "down": prefix + ("ssm_inner", "embed"),
    }


def mlstm_state0(cfg, batch):
    NH = cfg.num_heads
    dh = _inner(cfg) // NH
    return {
        "c": jnp.zeros((batch, NH, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, NH, dh), jnp.float32),
        "m": jnp.zeros((batch, NH), jnp.float32),
        "conv": jnp.zeros((batch, 3, _inner(cfg)), cfg.dtype),
    }


def _mlstm_step(p, cfg, st, x_t, valid_t):
    """One token. x_t: (B, d); valid_t: (B,) bool. Returns (st, y (B,d))."""
    B = x_t.shape[0]
    NH = cfg.num_heads
    inner = _inner(cfg)
    dh = inner // NH
    h = nn.rmsnorm(p["ln"], x_t[:, None, :], cfg.rms_eps)[:, 0]
    hu = h @ p["up"]                                # (B, 2*inner)
    h_gate, hx = jnp.split(hu, 2, axis=-1)
    # causal depthwise conv over the last 4 inputs (3 cached + current)
    win = jnp.concatenate([st["conv"], hx[:, None, :]], axis=1)  # (B,4,inner)
    h_conv = jax.nn.silu(jnp.einsum("bti,ti->bi", win.astype(jnp.float32),
                                    p["conv_w"].astype(jnp.float32)))
    h_conv = h_conv.astype(hx.dtype)
    q = (h_conv @ p["wq"]).reshape(B, NH, dh).astype(jnp.float32)
    k = ((h_conv @ p["wk"]) / math.sqrt(dh)).reshape(B, NH, dh).astype(jnp.float32)
    v = (hx @ p["wv"]).reshape(B, NH, dh).astype(jnp.float32)
    gates = h_conv.astype(jnp.float32) @ p["w_if"] + p["b_if"]   # (B, 2NH)
    i_t, f_t = jnp.split(gates.reshape(B, 2, NH), 2, axis=1)
    i_t, f_t = i_t[:, 0], f_t[:, 0]                 # (B, NH) pre-activations
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + st["m"], i_t)
    i_p = jnp.exp(i_t - m_new)[..., None]           # (B,NH,1)
    f_p = jnp.exp(logf + st["m"] - m_new)[..., None]
    c_new = f_p[..., None] * st["c"] + i_p[..., None] * (
        k[..., :, None] * v[..., None, :])          # (B,NH,dk,dv)
    n_new = f_p * st["n"] + i_p * k
    qn = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q))
    h_num = jnp.einsum("bhkv,bhk->bhv", c_new, q)
    # exact stabilized normalization: stored (C, n) carry an implicit
    # exp(-m) factor, so the true max(|q·n_true|, 1) lower bound becomes
    # exp(-m) here — keeps recurrent ≡ chunkwise forms bit-comparable
    h_t = h_num / jnp.maximum(qn, jnp.exp(-m_new))[..., None]   # (B,NH,dv)
    h_t = h_t.reshape(B, inner)
    h_t = (h_t * p["gn"].astype(jnp.float32)) * jax.nn.silu(
        h_gate.astype(jnp.float32))
    y = (h_t.astype(x_t.dtype) @ p["down"])

    # mask invalid rows: state unchanged, output zero
    vb = valid_t[:, None]
    new_st = {
        "c": jnp.where(valid_t[:, None, None, None], c_new, st["c"]),
        "n": jnp.where(valid_t[:, None, None], n_new, st["n"]),
        "m": jnp.where(vb, m_new, st["m"]),
        "conv": jnp.where(valid_t[:, None, None],
                          jnp.concatenate([st["conv"][:, 1:], hx[:, None, :]],
                                          axis=1), st["conv"]),
    }
    return new_st, jnp.where(vb, y, 0.0).astype(x_t.dtype)


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------
def init_slstm_block(key, cfg: ModelConfig):
    dt = cfg.dtype
    d, NH = cfg.d_model, cfg.num_heads
    dh = d // NH
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    pf = cfg.ssm.slstm_proj_factor if cfg.ssm else 1.334
    dff = int(d * pf)  # speclint: disable=host-sync -- static config arithmetic, not a traced value
    p = {
        "ln": nn.init_rmsnorm(d, dt)[0],
        "w": (jax.random.normal(ks[0], (d, 4 * d)) * s).astype(jnp.float32),
        "r": (jax.random.normal(ks[1], (NH, dh, 4 * dh)) / math.sqrt(dh)
              ).astype(jnp.float32),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "gn": jnp.ones((d,), dt),
        "ffn": nn.init_swiglu(ks[2], d, dff, dt)[0],
        "ln2": nn.init_rmsnorm(d, dt)[0],
    }
    return p


def slstm_axes(prefix):
    return {
        "ln": {"scale": prefix + ("embed",)},
        "w": prefix + ("embed", None),
        "r": prefix + ("heads", "head_dim", None),
        "b": prefix + (None,),
        "gn": prefix + ("embed",),
        "ffn": {"gate": {"w": prefix + ("embed", "mlp")},
                "up": {"w": prefix + ("embed", "mlp")},
                "down": {"w": prefix + ("mlp", "embed")}},
        "ln2": {"scale": prefix + ("embed",)},
    }


def slstm_state0(cfg, batch):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_step(p, cfg, st, x_t, valid_t):
    B = x_t.shape[0]
    d, NH = cfg.d_model, cfg.num_heads
    dh = d // NH
    h_in = nn.rmsnorm(p["ln"], x_t[:, None, :], cfg.rms_eps)[:, 0]
    zx = h_in.astype(jnp.float32) @ p["w"]                     # (B, 4d)
    h_prev = st["h"].reshape(B, NH, dh)
    zr = jnp.einsum("bhd,hdf->bhf", h_prev, p["r"]).reshape(B, 4 * d)
    z_all = (zx + zr + p["b"]).reshape(B, 4, d)
    zi, zf, zz, zo = z_all[:, 0], z_all[:, 1], z_all[:, 2], z_all[:, 3]
    logf = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(logf + st["m"], zi)
    i_p = jnp.exp(zi - m_new)
    f_p = jnp.exp(logf + st["m"] - m_new)
    c_new = f_p * st["c"] + i_p * jnp.tanh(zz)
    n_new = f_p * st["n"] + i_p
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1e-6)
    y = (h_new * p["gn"].astype(jnp.float32)).astype(x_t.dtype)

    vb = valid_t[:, None]
    new_st = {
        "c": jnp.where(vb, c_new, st["c"]),
        "n": jnp.where(vb, n_new, st["n"]),
        "h": jnp.where(vb, h_new, st["h"]),
        "m": jnp.where(vb, m_new, st["m"]),
    }
    return new_st, jnp.where(vb, y, 0.0).astype(x_t.dtype)


def _slstm_block(p, cfg, st, x_t, valid_t):
    st, y = _slstm_step(p, cfg, st, x_t, valid_t)
    x = x_t + y
    h2 = nn.rmsnorm(p["ln2"], x[:, None, :], cfg.rms_eps)[:, 0]
    return st, x + nn.swiglu(p["ffn"], h2[:, None, :])[:, 0]


def _mlstm_block(p, cfg, st, x_t, valid_t):
    st, y = _mlstm_step(p, cfg, st, x_t, valid_t)
    return st, x_t + y


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------
def _group_shape(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_groups, mlstm_per_group). slstm_every=k -> groups of (k-1) mLSTM
    + 1 sLSTM; slstm_every=0 -> one group of all-mLSTM, no sLSTM."""
    k = cfg.ssm.slstm_every if cfg.ssm else 0
    if k <= 0:
        return 1, cfg.num_layers
    assert cfg.num_layers % k == 0, (cfg.num_layers, k)
    return cfg.num_layers // k, k - 1


def param_axes(cfg: ModelConfig):
    axes = {
        "embed": ("vocab", "embed"),
        "mlstm": mlstm_axes(("layers", "layers2")),
        "final_norm": {"scale": ("embed",)},
    }
    if cfg.ssm and cfg.ssm.slstm_every > 0:
        axes["slstm"] = slstm_axes(("layers",))
    return axes


def init(key, cfg: ModelConfig):
    dt = cfg.dtype
    G, M = _group_shape(cfg)
    k_emb, k_m, k_s = jax.random.split(key, 3)
    mk = jax.random.split(k_m, G * M).reshape(G, M, 2)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dt),
        "mlstm": jax.vmap(jax.vmap(partial(init_mlstm_block, cfg=cfg)))(mk),
        "final_norm": nn.init_rmsnorm(cfg.d_model, dt)[0],
    }
    if cfg.ssm and cfg.ssm.slstm_every > 0:
        sk = jax.random.split(k_s, G)
        params["slstm"] = jax.vmap(partial(init_slstm_block, cfg=cfg))(sk)
    return params, param_axes(cfg)


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               with_snaps: bool = False):
    G, M = _group_shape(cfg)
    zeros_like_stack = lambda st, *lead: jax.tree.map(
        lambda x: jnp.zeros(lead + x.shape, x.dtype), st)
    m0 = mlstm_state0(cfg, batch)
    layers: Dict[str, Any] = {"mlstm": zeros_like_stack(m0, G, M)}
    # reset n to ones equivalent handled in state0 (zeros fine for mLSTM n)
    if cfg.ssm and cfg.ssm.slstm_every > 0:
        s0 = slstm_state0(cfg, batch)
        layers["slstm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (G,) + x.shape).copy(), s0)
    if with_snaps:
        layers["snaps"] = jax.tree.map(
            lambda x: jnp.zeros((SNAP_SLOTS,) + x.shape, x.dtype),
            {k: v for k, v in layers.items() if k != "snaps"})
    axes = jax.tree.map(lambda _: None, layers)
    axes["mlstm"] = {
        "c": (None, None, "batch", "heads", "ssm_dk", None),
        "n": (None, None, "batch", "heads", "ssm_dk"),
        "m": (None, None, "batch", "heads"),
        "conv": (None, None, "batch", None, "ssm_inner"),
    }
    if "slstm" in layers:
        axes["slstm"] = {k: (None, "batch", "embed")
                         for k in ("c", "n", "h", "m")}
    return layers, axes


def _run_tokens(params, cfg, layers, x_seq, valid_seq, ptr=None):
    """Scan over T tokens; inside, scan over layer groups.

    x_seq: (B, T, d); valid_seq: (B, T). Returns (layers, y (B,T,d))."""
    G, M = _group_shape(cfg)
    has_s = "slstm" in layers

    def token_step(lay, inp):
        x_t, valid_t = inp

        def group_step(x_t, g):
            def m_step(x_t, mm):
                st, x_t = _mlstm_block(mm["p"], cfg, mm["st"], x_t, valid_t)
                return x_t, st
            x_t, m_new = jax.lax.scan(
                m_step, x_t, {"p": g["mp"], "st": g["mst"]})
            out = {"mst": m_new}
            if has_s:
                s_new, x_t = _slstm_block(g["sp"], cfg, g["sst"], x_t, valid_t)
                out["sst"] = s_new
            return x_t, out

        gxs = {"mp": params["mlstm"], "mst": lay["mlstm"]}
        if has_s:
            gxs["sp"] = params["slstm"]
            gxs["sst"] = lay["slstm"]
        y_t, new = jax.lax.scan(group_step, x_t, gxs)
        new_lay = dict(lay)
        new_lay["mlstm"] = new["mst"]
        if has_s:
            new_lay["slstm"] = new["sst"]
        return new_lay, y_t

    lay = {k: v for k, v in layers.items() if k != "snaps"}
    x_tb = jnp.swapaxes(x_seq, 0, 1)          # (T, B, d)
    v_tb = jnp.swapaxes(valid_seq, 0, 1)

    # §Perf iteration 1 (EXPERIMENTS.md): chunked-remat time scan for long
    # sequences.  The naive scan saves every per-step (B,NH,dk,dv) matrix
    # state for backward (catastrophic at T=4096); checkpointing per
    # CHUNK_T-step chunk trades one recompute forward for ~CHUNK_T× less
    # saved-residual traffic.
    CHUNK_T = 64
    T = x_tb.shape[0]
    if "snaps" not in layers and T % CHUNK_T == 0 and T >= 2 * CHUNK_T:
        def chunk_step(lay, inp):
            x_c, v_c = inp                     # (CHUNK_T, B, …)
            def inner(lay, xv):
                return token_step(lay, xv)
            lay, y_c = jax.lax.scan(inner, lay, (x_c, v_c))
            return lay, y_c
        chunked = jax.checkpoint(
            chunk_step, policy=jax.checkpoint_policies.nothing_saveable)
        x_ck = x_tb.reshape(T // CHUNK_T, CHUNK_T, *x_tb.shape[1:])
        v_ck = v_tb.reshape(T // CHUNK_T, CHUNK_T, *v_tb.shape[1:])
        lay, y_ck = jax.lax.scan(chunked, lay, (x_ck, v_ck))
        return lay, jnp.swapaxes(y_ck.reshape(T, *y_ck.shape[2:]), 0, 1)

    if "snaps" in layers:
        ptr0 = jnp.int32(0) if ptr is None else ptr.astype(jnp.int32)

        def step_with_snap(carry, inp):
            lay, snaps, p = carry
            lay, y = token_step(lay, inp)
            snaps = jax.tree.map(
                lambda s, cur: kvc.snap_write(s, cur, p),
                snaps, {k: lay[k] for k in snaps})
            return (lay, snaps, p + 1), y
        (lay, snaps, _), y_tb = jax.lax.scan(
            step_with_snap, (lay, layers["snaps"], ptr0), (x_tb, v_tb))
        lay = dict(lay)
        lay["snaps"] = snaps
    else:
        lay, y_tb = jax.lax.scan(token_step, lay, (x_tb, v_tb))
    return lay, jnp.swapaxes(y_tb, 0, 1)


def forward_cached(params, cfg: ModelConfig, state: kvc.ModelState,
                   tokens, valid=None, logits_mode="all", **_ignored):
    B, T = tokens.shape
    if valid is None:
        valid = jnp.ones((B, T), jnp.bool_)
    state, q_pos, slot = kvc.append_tokens(state, tokens, valid)
    x = tf._embed(params, cfg, tokens)
    new_layers, y = _run_tokens(params, cfg, state.layers, x, valid, ptr=slot)
    state = dataclasses.replace(state, layers=new_layers)
    if logits_mode == "none":
        return None, state
    if logits_mode == "last":
        idx = jnp.maximum(jnp.sum(valid, axis=1) - 1, 0)
        y_last = jnp.take_along_axis(
            y, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return tf._unembed(params, cfg, y_last), state
    return tf._unembed(params, cfg, y), state


def _restore_leaf(snap, cur, slots, b_ax):
    """Per-row snapshot gather: out[..., b, ...] = snap[slots[b], ..., b, ...].

    snap: (K,) + cur.shape; batch axis of ``cur`` is ``b_ax``."""
    g = jnp.take(snap, slots, axis=0)          # (B,) + cur.shape
    g = jnp.moveaxis(g, b_ax + 1, 1)           # (B, B, rest...)
    B = cur.shape[b_ax]
    idx = jnp.arange(B)
    diag = g[idx, idx]                         # (B, rest...)
    return jnp.moveaxis(diag, 0, b_ax).astype(cur.dtype)


def rollback_ssm(state: kvc.ModelState, r: jnp.ndarray) -> kvc.ModelState:
    """Restore per-row recurrent state from the snapshot ring (DESIGN §5).

    r: (B,) number of tokens to roll back (suffix of the physical block).
    Snapshot slot (P-1-r[b]) holds row b's state after its last surviving
    token (invalid tokens were per-row no-ops, so slots are row-consistent).
    """
    layers = state.layers
    assert "snaps" in layers, "rollback_ssm requires snapshot-enabled cache"
    P = state.write_ptr
    slots = ((P - 1 - r.astype(jnp.int32)) % SNAP_SLOTS).astype(jnp.int32)

    new = dict(layers)
    new["mlstm"] = jax.tree.map(
        lambda s, c: _restore_leaf(s, c, slots, 2),
        layers["snaps"]["mlstm"], layers["mlstm"])
    if "slstm" in layers:
        new["slstm"] = jax.tree.map(
            lambda s, c: _restore_leaf(s, c, slots, 1),
            layers["snaps"]["slstm"], layers["slstm"])
    return dataclasses.replace(state, layers=new)


# ---------------------------------------------------------------------------
# Chunkwise-parallel mLSTM (§Perf iteration 2 — EXPERIMENTS.md):
# the recurrent form reads+writes the (B,NH,dk,dv) matrix memory EVERY
# time step; the chunkwise form (xLSTM paper App. A) carries state once
# per chunk and computes intra-chunk interactions as (L×L) masked matmuls
# — MXU-friendly and ~chunk× less state traffic.  Train path only; decode
# keeps the exact recurrent step.
# ---------------------------------------------------------------------------
MLSTM_CHUNK = 64


def _mlstm_block_chunkwise(p, cfg, x, chunk: int = MLSTM_CHUNK):
    """x: (B, S, d) -> (B, S, d) block output. All-valid sequences."""
    B, S, d = x.shape
    NH = cfg.num_heads
    inner = _inner(cfg)
    dh = inner // NH
    L = chunk
    NC = S // L
    h = nn.rmsnorm(p["ln"], x, cfg.rms_eps)
    hu = jnp.einsum("bsd,di->bsi", h, p["up"])
    h_gate, hx = jnp.split(hu, 2, axis=-1)
    # causal depthwise conv over 4 taps — shifted multiply-adds instead of
    # materializing a (B,S,4,inner) window stack (§Perf H3)
    pad = jnp.pad(hx, ((0, 0), (3, 0), (0, 0)))
    w_taps = p["conv_w"].astype(hx.dtype)
    acc = pad[:, 0:S] * w_taps[0]
    for i in range(1, 4):
        acc = acc + pad[:, i:i + S] * w_taps[i]
    h_conv = jax.nn.silu(acc.astype(jnp.float32)).astype(hx.dtype)
    q = (jnp.einsum("bsi,ij->bsj", h_conv, p["wq"])
         .reshape(B, S, NH, dh).astype(jnp.float32))
    k = (jnp.einsum("bsi,ij->bsj", h_conv, p["wk"]) / (dh ** 0.5)
         ).reshape(B, S, NH, dh).astype(jnp.float32)
    v = (jnp.einsum("bsi,ij->bsj", hx, p["wv"])
         .reshape(B, S, NH, dh).astype(jnp.float32))
    gates = h_conv.astype(jnp.float32) @ p["w_if"] + p["b_if"]   # (B,S,2NH)
    i_pre, f_pre = jnp.split(gates.reshape(B, S, 2, NH), 2, axis=2)
    i_pre, f_pre = i_pre[:, :, 0], f_pre[:, :, 0]                # (B,S,NH)
    logf = jax.nn.log_sigmoid(f_pre)

    # chunked views: (B, NC, L, ...)
    ck = lambda t: t.reshape(B, NC, L, *t.shape[2:])
    qc, kc, vc = ck(q), ck(k), ck(v)
    ic, fc = ck(i_pre), ck(logf)
    b = jnp.cumsum(fc, axis=2)              # (B,NC,L,NH) intra-chunk decay
    Btot = b[:, :, -1]                      # (B,NC,NH) total chunk decay

    def chunk_step(carry, inp):
        C, n, m = carry                     # (B,NH,dk,dv),(B,NH,dk),(B,NH)
        qj, kj, vj, ij, bj, Bj = inp        # (B,L,NH,·)
        # stabilizer for this chunk
        a_local = bj + ij                   # source weight log, (B,L,NH)
        m_intra = jnp.max(a_local, axis=1)  # over L -> (B,NH)
        m_new = jnp.maximum(m + Bj, m_intra)
        # inter-chunk contribution: q_t · C_prev, scaled exp(b_t + m - m_new)
        scale_t = jnp.exp(bj + m[:, None, :] - m_new[:, None, :])  # (B,L,NH)
        h_inter = jnp.einsum("blhk,bhkv->blhv", qj, C) * scale_t[..., None]
        n_inter = jnp.einsum("blhk,bhk->blh", qj, n) * scale_t
        # intra-chunk: D[t,s] = exp(b_t - b_s + i_s - m_new) for s <= t
        logD = (bj[:, :, None] - bj[:, None, :, :] + ij[:, None]
                - m_new[:, None, None, :])           # (B,L,L,NH)
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        D = jnp.where(mask, jnp.exp(logD), 0.0)
        scores = jnp.einsum("blhk,bshk->blsh", qj, kj) * D
        h_intra = jnp.einsum("blsh,bshv->blhv", scores, vj)
        n_intra = jnp.sum(scores, axis=2)            # (B,L,NH)
        # combine + normalize
        h_num = h_inter + h_intra
        n_tot = n_inter + n_intra
        denom = jnp.maximum(jnp.abs(n_tot), jnp.exp(-m_new[:, None, :]))
        h_out = h_num / denom[..., None]             # (B,L,NH,dv)
        # state update to end of chunk:
        # C_new = exp(B_j + m - m_new) C + Σ_s exp(B_j - b_s + i_s - m_new) k v
        w_s = jnp.exp(Bj[:, None, :] - bj + ij - m_new[:, None, :])  # (B,L,NH)
        C_new = (jnp.exp(Bj + m - m_new)[..., None, None] * C
                 + jnp.einsum("blhk,blhv->bhkv", kj * w_s[..., None], vj))
        n_new = (jnp.exp(Bj + m - m_new)[..., None] * n
                 + jnp.sum(kj * w_s[..., None], axis=1))
        return (C_new, n_new, m_new), h_out

    C0 = jnp.zeros((B, NH, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, NH, dh), jnp.float32)
    m0 = jnp.full((B, NH), -1e30, jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, ic, b, Btot))
    _, h_all = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h_all = jnp.moveaxis(h_all, 0, 1).reshape(B, S, NH, dh)
    h_flat = h_all.reshape(B, S, inner)
    h_flat = (h_flat * p["gn"].astype(jnp.float32)) * jax.nn.silu(
        h_gate.astype(jnp.float32))
    return x + (h_flat.astype(x.dtype) @ p["down"])


def forward_train(params, cfg: ModelConfig, tokens, remat=True,
                  chunkwise: bool = True, **_ignored):
    B, S = tokens.shape
    x = tf._embed(params, cfg, tokens)
    if chunkwise and S % MLSTM_CHUNK == 0 and S >= MLSTM_CHUNK \
            and (cfg.ssm is None or cfg.ssm.slstm_every == 0
                 or True):
        # chunkwise mLSTM; sLSTM blocks (strictly sequential by design)
        # keep the recurrent step but are a small minority of layers
        G, M = _group_shape(cfg)
        has_s = "slstm" in params

        def group_step(x, g):
            def m_step(x, mp):
                return _mlstm_block_chunkwise(mp, cfg, x), None
            x, _ = jax.lax.scan(m_step, x, g["mp"])
            if has_s:
                st = slstm_state0(cfg, B)
                def s_tok(carry, x_t):
                    st, = carry
                    st, y = _slstm_block(g["sp"], cfg, st, x_t,
                                         jnp.ones((B,), jnp.bool_))
                    return (st,), y
                def s_chunk(carry, x_c):
                    return jax.lax.scan(s_tok, carry, x_c)
                chunks = jnp.swapaxes(x, 0, 1).reshape(
                    S // MLSTM_CHUNK, MLSTM_CHUNK, B, -1)
                _, y = jax.lax.scan(
                    jax.checkpoint(s_chunk,
                                   policy=jax.checkpoint_policies
                                   .nothing_saveable),
                    (st,), chunks)
                x = jnp.swapaxes(y.reshape(S, B, -1), 0, 1)
            return x, None

        gxs = {"mp": params["mlstm"]}
        if has_s:
            gxs["sp"] = params["slstm"]
        fn = jax.checkpoint(group_step,
                            policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(fn, x, gxs)
        return tf._unembed(params, cfg, x)
    layers, _ = make_cache(cfg, B, 0, with_snaps=False)
    valid = jnp.ones((B, S), jnp.bool_)
    _, y = _run_tokens(params, cfg, layers, x, valid)
    return tf._unembed(params, cfg, y)
