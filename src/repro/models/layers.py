"""Core neural layers shared by every architecture family.

All layers are pure functions over parameter pytrees.  Every ``init_*``
returns ``(params, axes)`` where ``axes`` mirrors ``params`` with tuples of
*logical axis names* per dimension — consumed by ``repro.sharding`` to build
``NamedSharding``s with divisibility fallback.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# Logical axis vocabulary (see repro/sharding.py for the mesh mapping rules):
#   vocab, embed, heads, kv_heads, head_dim, mlp, experts, expert_mlp,
#   ssm_inner, ssm_state, conv, enc_embed, layers, batch, seq


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_linear(key, in_dim, out_dim, in_axis, out_axis, dtype,
                bias: bool = False, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": _normal(key, (in_dim, out_dim), dtype, scale)}
    a = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (out_axis,)
    return p, a


def linear(p, x):
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d, dtype):
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def softcap(x, cap: float):
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------
def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, T, H, D); positions: (B, T) int32 logical positions."""
    freqs = _rope_freqs(x.shape[-1], theta)                     # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs       # (B,T,D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                sections: Tuple[int, int, int], theta: float) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, T, H, D); positions3: (B, T, 3) — (t, h, w) position streams.
    ``sections`` partitions the D/2 frequency slots among the 3 streams.
    For pure text the 3 streams are identical -> reduces to standard RoPE.
    """
    assert sum(sections) == x.shape[-1] // 2, (sections, x.shape)
    freqs = _rope_freqs(x.shape[-1], theta)                     # (D/2,)
    # stream id per frequency slot
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                          # (B,T,3)
        jnp.broadcast_to(sec_ids[None, None, :],
                         positions3.shape[:2] + sec_ids.shape), axis=-1)
    ang = pos * freqs                                            # (B,T,D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, validity-mask aware — paper Eq. 8)
# ---------------------------------------------------------------------------
def build_attention_mask(cache_mask: jnp.ndarray,
                         kv_positions: jnp.ndarray,
                         q_positions: jnp.ndarray,
                         window: int = 0) -> jnp.ndarray:
    """The paper's Eq. 8: logical validity mask -> attention mask.

    cache_mask:   (B, S) bool — logical validity of each physical KV slot
    kv_positions: (B, S) int32 — logical position stored in each slot
    q_positions:  (B, T) int32 — logical positions of the query tokens
    window:       sliding-window size (0 = full)

    Returns (B, T, S) bool.  Invalid slots (mask=0) are ignored even though
    their data physically exists — this is what makes logical rollback free.
    """
    valid = cache_mask[:, None, :]                                    # (B,1,S)
    causal = kv_positions[:, None, :] <= q_positions[:, :, None]      # (B,T,S)
    m = valid & causal
    if window > 0:
        m = m & (kv_positions[:, None, :] > q_positions[:, :, None] - window)
    return m


def overlay_block_mask(m: jnp.ndarray, cache_mask: jnp.ndarray,
                       block_attend: jnp.ndarray,
                       region_start: jnp.ndarray) -> jnp.ndarray:
    """Overwrite the attention-mask columns of a speculative tree region
    with a static per-query override (tree-structured speculation).

    Tree nodes share logical positions with their siblings, so the purely
    positional causal mask of ``build_attention_mask`` would let a node
    attend to non-ancestors at shallower depth.  The override replaces the
    mask columns of the last-written tree slots with the tree's static
    ancestor-or-self matrix (rows for non-tree queries in the same block
    are all-False there, which matches what position causality yields).

    m:            (B, T, S) mask from ``build_attention_mask``
    cache_mask:   (B, S) post-append logical validity (gates retired /
                  inactive rows' tree slots out of the override too)
    block_attend: (T, R) static override for the region columns
    region_start: () int32 — first physical slot of the region; the region
                  is the R slots ``[region_start, region_start + R)``
    """
    T, R = block_attend.shape
    B = m.shape[0]
    region_valid = jax.lax.dynamic_slice(
        cache_mask, (jnp.int32(0), region_start), (B, R))        # (B, R)
    ov = block_attend[None, :, :] & region_valid[:, None, :]     # (B, T, R)
    return jax.lax.dynamic_update_slice(
        m, ov, (jnp.int32(0), jnp.int32(0), region_start))


def overlay_block_mask_at(m: jnp.ndarray, cache_mask: jnp.ndarray,
                          block_attend: jnp.ndarray,
                          cols: jnp.ndarray) -> jnp.ndarray:
    """Per-row variant of ``overlay_block_mask`` for paged states: each
    row's tree region lives at its OWN slots ``cols`` (B, R) — the row-local
    slots the append returned for the region's entries.  Rows that sat out
    the cycle carry the append's far-future sentinel and are dropped.

    m:            (B, T, S) mask from ``build_attention_mask``
    cache_mask:   (B, S) post-append logical validity
    block_attend: (T, R) static ancestor-or-self override
    cols:         (B, R) int32 row-local region slots (sentinel -> skip row)
    """
    T, R = block_attend.shape
    B, S = cache_mask.shape
    safe = jnp.clip(cols, 0, S - 1)
    region_valid = jnp.take_along_axis(cache_mask, safe, axis=1)  # (B, R)
    ov = block_attend[None, :, :] & region_valid[:, None, :]      # (B, T, R)
    return m.at[jnp.arange(B)[:, None, None],
                jnp.arange(T)[None, :, None],
                cols[:, None, :]].set(ov, mode="drop")


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  mask: jnp.ndarray, attn_softcap: float = 0.0,
                  scale: float | None = None) -> jnp.ndarray:
    """q: (B,T,H,D); k,v: (B,S,Hkv,D); mask: (B,T,S) -> (B,T,H,D)."""
    B, T, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, g, D)
    # §Perf G1 (EXPERIMENTS.md pair 3): mixed-precision dots with fp32
    # accumulation instead of materializing fp32 casts of the KV cache —
    # the cast alone tripled decode HBM traffic (read bf16 + write f32 +
    # read f32) on a tensor that dominates serving memory.
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, attn_softcap)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (fully masked) -> zeros, not NaN
    any_valid = jnp.any(mask, axis=-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bhgts,bshd->bthgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, T, H, D).astype(q.dtype)


def gqa_attention_quant(q: jnp.ndarray,
                        k_q: jnp.ndarray, k_scale: jnp.ndarray,
                        v_q: jnp.ndarray, v_scale: jnp.ndarray,
                        mask: jnp.ndarray, attn_softcap: float = 0.0,
                        scale: float | None = None) -> jnp.ndarray:
    """§Perf G2b: int8-KV attention WITHOUT dequant materialization.

    The per-(token, head) scales are constant over the contraction dims, so
    they factor OUT of both dots:
      QK: scores = (q_i8 · k_i8)[int32] · qs_t · ks_s
      PV: out    = Σ_s (p_s · vs_s) · v_i8[s]   (probs absorbed the scale)
    The dots run int8×int8 → int32 (native MXU int8 throughput); only the
    tiny (B,S,Hkv) scale vectors and the int8 cache touch HBM.
    q: (B,T,H,D) float; k_q/v_q: (B,S,Hkv,D) int8; *_scale: (B,S,Hkv).
    """
    B, T, H, D = q.shape
    Hkv = k_q.shape[2]
    g = H // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(D)
    # quantize q per (b, t, h)
    qg = q.reshape(B, T, Hkv, g, D)
    q_amax = jnp.max(jnp.abs(qg.astype(jnp.float32)), axis=-1)
    q_s = jnp.maximum(q_amax / 127.0, 1e-8)
    q_i8 = jnp.clip(jnp.round(qg.astype(jnp.float32) / q_s[..., None]),
                    -127, 127).astype(jnp.int8)
    scores_i = jnp.einsum("bthgd,bshd->bhgts", q_i8, k_q,
                          preferred_element_type=jnp.int32)
    scores = (scores_i.astype(jnp.float32)
              * jnp.moveaxis(q_s, (1, 2, 3), (3, 1, 2))[..., None]
              * k_scale.astype(jnp.float32).transpose(0, 2, 1)[
                  :, :, None, None, :]) * sc
    scores = softcap(scores, attn_softcap)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)
    any_valid = jnp.any(mask, axis=-1)[:, None, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    # absorb v scales into probs, quantize probs (max<=1 -> fixed scale)
    p_scaled = probs * v_scale.astype(jnp.float32).transpose(0, 2, 1)[
        :, :, None, None, :]
    p_amax = jnp.maximum(jnp.max(p_scaled, axis=-1), 1e-8)   # (b,h,g,t)
    p_i8 = jnp.clip(jnp.round(p_scaled / p_amax[..., None] * 127.0),
                    0, 127).astype(jnp.int8)
    out_i = jnp.einsum("bhgts,bshd->bthgd", p_i8, v_q,
                       preferred_element_type=jnp.int32)
    out = (out_i.astype(jnp.float32)
           * jnp.moveaxis(p_amax, (1, 2, 3), (2, 3, 1))[..., None] / 127.0)
    return out.reshape(B, T, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_swiglu(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    p["gate"], a["gate"] = init_linear(k1, d_model, d_ff, "embed", "mlp", dtype)
    p["up"], a["up"] = init_linear(k2, d_model, d_ff, "embed", "mlp", dtype)
    p["down"], a["down"] = init_linear(k3, d_ff, d_model, "mlp", "embed", dtype)
    return p, a


def swiglu(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def init_gelu_mlp(key, d_model, d_ff, dtype, bias=True):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["up"], a["up"] = init_linear(k1, d_model, d_ff, "embed", "mlp", dtype, bias=bias)
    p["down"], a["down"] = init_linear(k2, d_ff, d_model, "mlp", "embed", dtype, bias=bias)
    return p, a


def gelu_mlp(p, x):
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))


# ---------------------------------------------------------------------------
# Attention block params
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype, kv_input_dim: Optional[int] = None):
    """Standard GQA projections. kv_input_dim overrides K/V input width
    (whisper cross-attention reads encoder states)."""
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kv_in = kv_input_dim or d
    kq, kk, kv_, ko = jax.random.split(key, 4)
    kv_axis = "enc_embed" if kv_input_dim else "embed"
    p, a = {}, {}
    p["q"], a["q"] = init_linear(kq, d, H * hd, "embed", "heads", dtype, bias=cfg.qkv_bias)
    p["k"], a["k"] = init_linear(kk, kv_in, Hkv * hd, kv_axis, "kv_heads", dtype, bias=cfg.qkv_bias)
    p["v"], a["v"] = init_linear(kv_, kv_in, Hkv * hd, kv_axis, "kv_heads", dtype, bias=cfg.qkv_bias)
    p["o"], a["o"] = init_linear(ko, H * hd, d, "heads", "embed", dtype)
    return p, a


def attention_qkv(p, x, cfg, kv_x=None):
    """Project to q,k,v. x: (B,T,d). Returns q:(B,T,H,hd) k,v:(B,Tk,Hkv,hd)."""
    B, T, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    Tk = kv_x.shape[1]
    q = linear(p["q"], x).reshape(B, T, cfg.num_heads, cfg.head_dim)
    k = linear(p["k"], kv_x).reshape(B, Tk, cfg.num_kv_heads, cfg.head_dim)
    v = linear(p["v"], kv_x).reshape(B, Tk, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def attention_out(p, o):
    B, T, H, D = o.shape
    return linear(p["o"], o.reshape(B, T, H * D))
