"""Public jit'd wrappers for the Pallas kernels.

Handle padding to tile boundaries, dtype plumbing, and backend selection:
on TPU the kernels run compiled; on this CPU host they run in interpret
mode (same kernel body, Python-executed) — correctness is validated against
the ref.py oracles either way.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec

from . import attention as _attn
from . import dtv as _dtv
from . import verify as _verify
from . import ref

_INTERPRET = jax.default_backend() != "tpu"


def _force_replicated(*arrays):
    """Pallas kernels are OPAQUE to the GSPMD partitioner: given sharded
    operands it can run the kernel per-shard (partial softmax over a split
    head/seq dim — numerically wrong), not insert collectives.  Under an
    active multi-device mesh (the mesh-sharded serving path traces every
    program inside ``with placement.mesh:`` — see Executor), constrain all
    operands to replicated so the kernel always sees full arrays; XLA then
    places the gather collectives OUTSIDE the kernel.  With no mesh
    context (the trivial placement) this is a no-op and the lowering is
    byte-identical to the unmeshed path."""
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or mesh.size == 1:
        return arrays if len(arrays) > 1 else arrays[0]
    rep = NamedSharding(mesh, PartitionSpec())
    out = tuple(jax.lax.with_sharding_constraint(a, rep) for a in arrays)
    return out if len(out) > 1 else out[0]


def _pad_to(x, mult, axis, value):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("use_kernel",))
def dtv(a_logits: jnp.ndarray, b_logits: jnp.ndarray,
        use_kernel: bool = True) -> jnp.ndarray:
    """(B, V) x2 -> (B,) total variation distance (paper Eq. 5)."""
    if not use_kernel:
        return ref.dtv_ref(a_logits, b_logits)
    B, V = a_logits.shape
    a = _pad_to(_pad_to(a_logits, _dtv.BLK_V, 1, _dtv.NEG),
                _dtv.BLK_R, 0, _dtv.NEG)
    b = _pad_to(_pad_to(b_logits, _dtv.BLK_V, 1, _dtv.NEG),
                _dtv.BLK_R, 0, _dtv.NEG)
    a, b = _force_replicated(a, b)
    return _dtv.dtv_pallas(a, b, interpret=_INTERPRET)[:B]


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("use_kernel",))
def verify_row_stats(logits: jnp.ndarray, cand: jnp.ndarray,
                     use_kernel: bool = True):
    """logits: (R, V); cand: (R,) -> (argmax, max, sumexp, cand_logit)."""
    if not use_kernel:
        return ref.verify_stats_ref(logits, cand)
    R, V = logits.shape
    x = _pad_to(_pad_to(logits, _verify.BLK_V, 1, _verify.NEG),
                _verify.BLK_R, 0, _verify.NEG)
    c = _pad_to(cand.astype(jnp.int32), _verify.BLK_R, 0, 0)
    x, c = _force_replicated(x, c)
    am, m, s, cl = _verify.verify_stats_pallas(x, c, interpret=_INTERPRET)
    return am[:R], m[:R], s[:R], cl[:R]


@partial(jax.jit, static_argnames=("k", "use_kernel"))
def draft_topk(logits: jnp.ndarray, k: int, use_kernel: bool = True):
    """logits: (R, V) -> (values (R, k), indices (R, k)).

    Greedy tree-draft expansion: every parent node's top-k children in one
    fused pass over vocab tiles.  Tie-breaking matches jnp.argmax (first
    maximal index), so column 0 is bit-identical to linear greedy drafting.
    """
    if not use_kernel:
        return ref.topk_ref(logits, k)
    R, V = logits.shape
    x = _pad_to(_pad_to(logits, _verify.BLK_V, 1, _verify.NEG),
                _verify.BLK_R, 0, _verify.NEG)
    x = _force_replicated(x)
    vals, idx = _verify.topk_pallas(x, k, interpret=_INTERPRET)
    return vals[:R], idx[:R]


def greedy_accept_from_stats(cand, am, m, s, cl):
    """O(R) epilogue: greedy accept mask + p(cand) from the fused stats."""
    match = am == cand.astype(jnp.int32)
    p_cand = jnp.exp(cl - m) / jnp.maximum(s, 1e-30)
    return match, p_cand


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("use_kernel",))
def masked_decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            mask: jnp.ndarray,
                            use_kernel: bool = True) -> jnp.ndarray:
    """q: (B, H, D); k, v: (B, S, Hkv, D); mask: (B, S) -> (B, H, D)."""
    if not use_kernel:
        return ref.masked_decode_attention_ref(q, k, v, mask)
    D = q.shape[-1]
    scale = 1.0 / (D ** 0.5)     # scale by TRUE head dim before padding
    qp = _pad_to(q, 128, 2, 0.0)
    kp = _pad_to(k, 128, 3, 0.0)
    vp = _pad_to(v, 128, 3, 0.0)
    S = k.shape[1]
    kp = _pad_to(kp, _attn.BLK_S, 1, 0.0)
    vp = _pad_to(vp, _attn.BLK_S, 1, 0.0)
    mp = _pad_to(mask, _attn.BLK_S, 1, False)
    qp, kp, vp, mp = _force_replicated(qp, kp, vp, mp)
    out = _attn.masked_decode_attention_pallas(
        qp, kp, vp, mp, scale=scale, interpret=_INTERPRET)
    return out[:, :, :D]


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("use_kernel",))
def masked_tree_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          mask: jnp.ndarray,
                          use_kernel: bool = True) -> jnp.ndarray:
    """Tree-block decode attention: q: (B, T, H, D); k, v: (B, S, Hkv, D);
    mask: (B, T, S) per-query rows (ancestor-or-self over the speculative
    tree slots, validity-causal elsewhere) -> (B, T, H, D).

    The linear decode step is the T=1 special case (same mask path)."""
    if not use_kernel:
        return ref.masked_tree_attention_ref(q, k, v, mask)
    D = q.shape[-1]
    scale = 1.0 / (D ** 0.5)     # scale by TRUE head dim before padding
    qp = _pad_to(q, 128, 3, 0.0)
    kp = _pad_to(k, 128, 3, 0.0)
    vp = _pad_to(v, 128, 3, 0.0)
    kp = _pad_to(kp, _attn.BLK_S, 1, 0.0)
    vp = _pad_to(vp, _attn.BLK_S, 1, 0.0)
    mp = _pad_to(mask, _attn.BLK_S, 2, False)
    qp, kp, vp, mp = _force_replicated(qp, kp, vp, mp)
    out = _attn.masked_tree_attention_pallas(
        qp, kp, vp, mp, scale=scale, interpret=_INTERPRET)
    return out[:, :, :, :D]


# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("block_size", "use_kernel"))
def paged_decode_attention(q: jnp.ndarray, k_flat: jnp.ndarray,
                           v_flat: jnp.ndarray, block_table: jnp.ndarray,
                           mask: jnp.ndarray, block_size: int,
                           use_kernel: bool = True) -> jnp.ndarray:
    """Paged flash-decode over a block pool (the paged-KV serving path).

    q: (B, T, H, D); k_flat, v_flat: (P·bs, Hkv, D) — the flat pool layout
    ``PagedModelState`` stores per layer; block_table: (B, R) int32 with
    -1 marking unallocated row blocks; mask: (B, T, S) per-query validity
    rows, S = R·bs.  T=1 is paged single-token decode; T>1 with
    ancestor-mask rows is the paged tree-block case — one kernel subsumes
    both.  Unallocated table entries are clamped to pool block 0; their
    mask columns are False so they never reach the online softmax.
    """
    if not use_kernel:
        P = k_flat.shape[0] // block_size
        kp = k_flat.reshape(P, block_size, *k_flat.shape[1:])
        vp = v_flat.reshape(P, block_size, *v_flat.shape[1:])
        return ref.paged_attention_ref(q, kp, vp, block_table, mask)
    D = q.shape[-1]
    scale = 1.0 / (D ** 0.5)     # scale by TRUE head dim before padding
    qp = _pad_to(q, 128, 3, 0.0)
    kf = _pad_to(k_flat, 128, 2, 0.0)
    vf = _pad_to(v_flat, 128, 2, 0.0)
    P = kf.shape[0] // block_size
    kp = kf.reshape(P, block_size, *kf.shape[1:])
    vp = vf.reshape(P, block_size, *vf.shape[1:])
    tbl = jnp.clip(block_table, 0, P - 1)
    qp, kp, vp, tbl, mask = _force_replicated(qp, kp, vp, tbl, mask)
    out = _attn.paged_flash_decode_pallas(
        qp, kp, vp, tbl, mask, scale=scale, interpret=_INTERPRET)
    return out[:, :, :, :D]
