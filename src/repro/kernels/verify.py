"""Pallas TPU kernels: fused verification row statistics + draft top-k.

Each speculative step verifies B·(W+1) rows of |V|-wide logits (|V| up to
262k).  The naive path reads the logits 3×
(argmax, softmax-normalizer, token gather); this kernel fuses all of it in
ONE pass over vocab tiles:

    per row:  argmax, running max, rescaled sumexp, logit[cand]

The acceptance rule itself (greedy match / rejection sampling on p(cand))
is O(B·W) epilogue work done in plain jnp (see ops.verify_row_stats users).

``topk_pallas`` serves tree-structured speculation: greedy tree drafting
expands every parent node into its top-k children, which is a row-wise
top-k over the same |V|-wide logits.  One pass over vocab tiles keeps a
running (value, index) top-k per row (K is tiny and static), with
argmax-compatible tie-breaking (first maximal index wins) so the k=1
column is bit-identical to linear greedy drafting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_R = 8
BLK_V = 2048
NEG = -1e30


def _verify_kernel(x_ref, cand_ref, am_ref, m_ref, s_ref, cl_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        am_ref[...] = jnp.zeros_like(am_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        cl_ref[...] = jnp.full_like(cl_ref, NEG)

    x = x_ref[...].astype(jnp.float32)                  # (BLK_R, BLK_V)
    base = j * BLK_V
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + base

    # running argmax: strictly-greater keeps the FIRST maximal index,
    # matching jnp.argmax tie-breaking (scan left to right over tiles)
    m_old = m_ref[...]                                   # (BLK_R, 1)
    tile_max = jnp.max(x, axis=-1, keepdims=True)
    tile_arg = jnp.argmax(x, axis=-1).astype(jnp.int32)[:, None] + base
    better = tile_max > m_old
    am_ref[...] = jnp.where(better, tile_arg, am_ref[...])

    m_new = jnp.maximum(m_old, tile_max)
    s_ref[...] = (s_ref[...] * jnp.exp(m_old - m_new)
                  + jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True))
    m_ref[...] = m_new

    # candidate logit gather: the candidate column lands in exactly one tile
    hit = col == cand_ref[...]                           # (BLK_R, BLK_V)
    cl_tile = jnp.max(jnp.where(hit, x, NEG), axis=-1, keepdims=True)
    cl_ref[...] = jnp.maximum(cl_ref[...], cl_tile)


def verify_stats_pallas(logits: jnp.ndarray, cand: jnp.ndarray,
                        interpret: bool = True):
    """logits: (R, V) padded; cand: (R,) int32.

    Returns (argmax (R,), max (R,), sumexp (R,), cand_logit (R,))."""
    R, V = logits.shape
    grid = (R // BLK_R, V // BLK_V)
    cand2 = cand.astype(jnp.int32)[:, None]
    am, m, s, cl = pl.pallas_call(
        _verify_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLK_R, BLK_V), lambda i, j: (i, j)),
                  pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0))],
        out_specs=[pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, 1), jnp.int32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(logits, cand2)
    return am[:, 0], m[:, 0], s[:, 0], cl[:, 0]


# ---------------------------------------------------------------------------
# Row-wise top-k over vocab tiles (greedy tree-draft expansion)
# ---------------------------------------------------------------------------
def _select_topk(vals, idx, K):
    """(R, C) candidates -> (R, K) selected, first-maximal-index ties.
    K and C are static and tiny; K rounds of masked argmax on the VPU."""
    BIG = jnp.int32(2**30)
    out_v, out_i = [], []
    for _ in range(K):
        vmax = jnp.max(vals, axis=-1, keepdims=True)
        # among entries equal to the max, take the smallest index
        imin = jnp.min(jnp.where(vals >= vmax, idx, BIG), axis=-1,
                       keepdims=True)
        out_v.append(vmax)
        out_i.append(imin)
        vals = jnp.where(idx == imin, NEG, vals)   # retire the winner
    return jnp.concatenate(out_v, -1), jnp.concatenate(out_i, -1)


def _topk_kernel(x_ref, v_ref, i_ref, *, K):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        v_ref[...] = jnp.full_like(v_ref, NEG)
        i_ref[...] = jnp.zeros_like(i_ref)

    x = x_ref[...].astype(jnp.float32)                   # (BLK_R, BLK_V)
    base = j * BLK_V
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + base
    tv, ti = _select_topk(x, col, K)                     # tile top-K
    # merge with the running top-K: running entries carry SMALLER indices
    # than anything in this tile, so putting them first preserves the
    # first-maximal-index tie-break through the re-selection
    mv = jnp.concatenate([v_ref[...], tv], axis=-1)      # (BLK_R, 2K)
    mi = jnp.concatenate([i_ref[...], ti], axis=-1)
    nv, ni = _select_topk(mv, mi, K)
    v_ref[...] = nv
    i_ref[...] = ni


def topk_pallas(logits: jnp.ndarray, k: int, interpret: bool = True):
    """logits: (R, V) padded to tile boundaries; returns
    (values (R, k) f32, indices (R, k) i32), argmax tie-breaking."""
    R, V = logits.shape
    grid = (R // BLK_R, V // BLK_V)
    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, K=k),
        grid=grid,
        in_specs=[pl.BlockSpec((BLK_R, BLK_V), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((BLK_R, k), lambda i, j: (i, 0)),
                   pl.BlockSpec((BLK_R, k), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, k), jnp.float32),
                   jax.ShapeDtypeStruct((R, k), jnp.int32)],
        interpret=interpret,
    )(logits)
    return vals, idx
