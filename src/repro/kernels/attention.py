"""Pallas TPU kernels: masked decode attention (flash-decode) — single
token and tree-block variants.

The paper's cache_mask (Eq. 8) is consumed INSIDE the kernel: invalid KV
slots never contribute to the online softmax, so logical rollback costs
nothing at attention time.  GQA: the g query heads sharing one KV head are
processed together as the (g × BLK_S) MXU tile.

Tree-structured speculation extends the same mask path: a cycle's T tree
nodes decode as one query block with a PER-QUERY mask row (B, T, S) —
ancestor-or-self over the tree slots (siblings share a RoPE position but
must not attend each other), plain validity-causal everywhere else (see
``layers.overlay_block_mask`` for the layout).  The single-token decode
kernel is exactly the T=1 special case.

Grid: (B, Hkv, S/BLK_S) — the minor S axis is sequential on TPU, so the
(m, l, acc) accumulators live in revisited output blocks; the wrapper
normalizes acc/l at the end (no in-kernel finalization step needed).

Paged variant (``paged_flash_decode_pallas``): the KV cache is a POOL of
fixed-size blocks (P, bs, Hkv, D) addressed through a per-row block table.
The block table is a *scalar-prefetch* argument: the grid's minor axis
walks each row's table entries and the K/V BlockSpec index maps read
``table[b, r]`` to DMA exactly that pool block into VMEM — on the TPU
path the gather IS the pipeline, no materialized per-row view.  (The
CPU/jnp forward in models/ materializes the gathered view and runs the
jnp attention instead — the repo-wide staging convention; this kernel is
held to the same oracle, ``ref.paged_attention_ref``, until the TPU
serving path wires it in.)  The kernel body is byte-identical to the tree
kernel's online softmax (T queries, per-query mask rows), so it subsumes
both the single-token (T=1) and tree-block decode cases.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLK_S = 512
NEG = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref,
                 *, scale):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (BLK_S, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # (BLK_S, D)
    msk = mask_ref[0]                                     # (BLK_S,)

    scores = q @ k.T                                      # (g, BLK_S)
    scores = jnp.where(msk[None, :], scores, NEG)

    m_old = m_ref[0, 0][:, :1]                            # (g, 1)
    m_new = jnp.maximum(m_old, jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.where(scores > NEG * 0.5, jnp.exp(scores - m_new), 0.0)
    corr = jnp.where(m_old > NEG * 0.5, jnp.exp(m_old - m_new), 0.0)

    l_ref[0, 0] = jnp.broadcast_to(
        l_ref[0, 0][:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True),
        l_ref[0, 0].shape)
    acc_ref[0, 0] = acc_ref[0, 0] * corr + p @ v
    m_ref[0, 0] = jnp.broadcast_to(m_new, m_ref[0, 0].shape)


def masked_decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray,
                                   v: jnp.ndarray, mask: jnp.ndarray,
                                   scale: float | None = None,
                                   interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, D); k, v: (B, S, Hkv, D); mask: (B, S).

    S must be a BLK_S multiple and D 128-aligned (ops.py pads)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, g, D)
    grid = (B, Hkv, S // BLK_S)

    acc, m, l = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, BLK_S, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, BLK_S, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, BLK_S), lambda b, h, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda b, h, s: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g, 128), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, mask)

    l1 = l[..., :1]
    out = jnp.where(l1 > 0, acc / jnp.maximum(l1, 1e-30), 0.0)
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Tree-block decode attention: T queries, per-query ancestor mask
# ---------------------------------------------------------------------------
def _tree_attn_kernel(q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref,
                      *, scale):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0].astype(jnp.float32) * scale        # (T, g, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)             # (BLK_S, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)             # (BLK_S, D)
    msk = mask_ref[0]                                      # (T, BLK_S)
    T, g, D = q.shape

    scores = (q.reshape(T * g, D) @ k.T).reshape(T, g, -1)  # (T, g, BLK_S)
    scores = jnp.where(msk[:, None, :], scores, NEG).reshape(T * g, -1)

    m_old = m_ref[0, 0].reshape(T * g, -1)[:, :1]          # (T*g, 1)
    m_new = jnp.maximum(m_old, jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.where(scores > NEG * 0.5, jnp.exp(scores - m_new), 0.0)
    corr = jnp.where(m_old > NEG * 0.5, jnp.exp(m_old - m_new), 0.0)

    l_old = l_ref[0, 0].reshape(T * g, -1)[:, :1]
    l_new = l_old * corr + jnp.sum(p, axis=-1, keepdims=True)
    l_ref[0, 0] = jnp.broadcast_to(l_new, (T * g, 128)).reshape(T, g, 128)
    acc = acc_ref[0, 0].reshape(T * g, D)
    acc_ref[0, 0] = (acc * corr + p @ v).reshape(T, g, D)
    m_ref[0, 0] = jnp.broadcast_to(m_new, (T * g, 128)).reshape(T, g, 128)


def masked_tree_attention_pallas(q: jnp.ndarray, k: jnp.ndarray,
                                 v: jnp.ndarray, mask: jnp.ndarray,
                                 scale: float | None = None,
                                 interpret: bool = True) -> jnp.ndarray:
    """q: (B, T, H, D); k, v: (B, S, Hkv, D); mask: (B, T, S) per-query
    (tree-ancestor rows over the speculative block, validity-causal rows
    elsewhere).  S must be a BLK_S multiple and D 128-aligned (ops.py
    pads).  T=1 with a (B, 1, S) mask reproduces the single-token kernel.
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, T, Hkv, g, D)
    grid = (B, Hkv, S // BLK_S)

    acc, m, l = pl.pallas_call(
        functools.partial(_tree_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, 1, g, D), lambda b, h, s: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, BLK_S, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, BLK_S, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, T, BLK_S), lambda b, h, s: (b, 0, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, g, D), lambda b, h, s: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, T, g, 128), lambda b, h, s: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, T, g, 128), lambda b, h, s: (b, h, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, T, g, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, T, g, 128), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, T, g, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, mask)

    l1 = l[..., :1]
    out = jnp.where(l1 > 0, acc / jnp.maximum(l1, 1e-30), 0.0)
    # (B, Hkv, T, g, D) -> (B, T, H, D)
    return out.swapaxes(1, 2).reshape(B, T, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged flash-decode: gather K/V block-by-block through the block table
# ---------------------------------------------------------------------------
def _paged_attn_kernel(table_ref, q_ref, k_ref, v_ref, mask_ref,
                       acc_ref, m_ref, l_ref, *, scale):
    # table_ref is consumed by the BlockSpec index maps (scalar prefetch);
    # the body is exactly the tree kernel's online softmax over one block.
    _tree_attn_kernel(q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref,
                      scale=scale)


def paged_flash_decode_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                              v_pool: jnp.ndarray,
                              block_table: jnp.ndarray,
                              mask: jnp.ndarray,
                              scale: float | None = None,
                              interpret: bool = True) -> jnp.ndarray:
    """q: (B, T, H, D); k_pool, v_pool: (P, bs, Hkv, D) block pools;
    block_table: (B, R) int32 pool block per row-local block (entries must
    be pre-clamped to [0, P) — unallocated blocks are mask-False anyway);
    mask: (B, T, S) per-query validity rows with S = R * bs.

    Grid (B, Hkv, R): the minor axis walks the row's block table; the K/V
    index maps dereference ``table[b, r]`` so each pool block is DMA'd
    exactly once per (row, kv-head).  T=1 gives paged single-token decode;
    T>1 with ancestor-mask rows gives paged tree-block decode.  On the TPU
    path bs should be a multiple of 8 (sublane) and D 128-aligned
    (ops.py pads D; bs is a build-time choice).
    """
    B, T, H, D = q.shape
    P, bs, Hkv, _ = k_pool.shape
    R = block_table.shape[1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, T, Hkv, g, D)
    tbl = block_table.reshape(-1).astype(jnp.int32)       # (B*R,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, R),
        in_specs=[
            pl.BlockSpec((1, T, 1, g, D), lambda b, h, r, t: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, r, t: (t[b * R + r], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, r, t: (t[b * R + r], 0, h, 0)),
            pl.BlockSpec((1, T, bs), lambda b, h, r, t: (b, 0, r)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, g, D), lambda b, h, r, t: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, T, g, 128), lambda b, h, r, t: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, T, g, 128), lambda b, h, r, t: (b, h, 0, 0, 0)),
        ],
    )
    acc, m, l = pl.pallas_call(
        functools.partial(_paged_attn_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, T, g, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, T, g, 128), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, T, g, 128), jnp.float32),
        ],
        interpret=interpret,
    )(tbl, qg, k_pool, v_pool, mask)

    l1 = l[..., :1]
    out = jnp.where(l1 > 0, acc / jnp.maximum(l1, 1e-30), 0.0)
    return out.swapaxes(1, 2).reshape(B, T, H, D).astype(q.dtype)
