"""Pallas TPU kernel: Total Variation Distance over a large vocabulary
(paper Eq. 5 — the SimScore probe runs this against up-to-262k vocabs).

Two single-pass kernels over vocab tiles:
  1. ``softmax_stats``: online (max, rescaled-sum) accumulation — one read
     of the logits.
  2. ``dtv_accum``: given both rows' normalizers, accumulates
     0.5·Σ|p − q| tile by tile.

VMEM budget per grid step: 2 tiles of (BLK_R × BLK_V) f32 plus (BLK_R × 1)
accumulators — (8 × 2048) tiles ≈ 128 KiB, far under the ~16 MiB VMEM of a
v5e core, and the 2048 lane dim is 128-aligned for the VPU.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_R = 8          # rows per tile (sublane-aligned)
BLK_V = 2048       # vocab lanes per tile (128-aligned)
NEG = -1e30


def dtv_probs(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """0.5 · Σ_v |p − q| over the last axis (paper Eq. 5), probability
    domain.  The single DTV definition shared by every on-device consumer:
    the per-op verify math AND the fused cycle program import it from here,
    so the similarity signal is identical whichever path produced it.  The
    Pallas kernels below are the logits-domain variant for probe-time
    comparisons over vocabularies too large to materialize as probs."""
    return 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)


# ---------------------------------------------------------------------------
# Kernel 1: online softmax statistics
# ---------------------------------------------------------------------------
def _stats_kernel(x_ref, m_ref, s_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[...].astype(jnp.float32)          # (BLK_R, BLK_V)
    m_old = m_ref[...]                          # (BLK_R, 1)
    m_tile = jnp.max(x, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_old, m_tile)
    s_tile = jnp.sum(jnp.exp(x - m_new), axis=-1, keepdims=True)
    s_ref[...] = s_ref[...] * jnp.exp(m_old - m_new) + s_tile
    m_ref[...] = m_new


def softmax_stats(logits: jnp.ndarray, interpret: bool = True):
    """(R, V) -> (max (R, 1), sumexp (R, 1)); V, R padded by caller."""
    R, V = logits.shape
    grid = (R // BLK_R, V // BLK_V)
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLK_R, BLK_V), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)],
        interpret=interpret,
    )(logits)


# ---------------------------------------------------------------------------
# Kernel 2: |p - q| accumulation given normalizers
# ---------------------------------------------------------------------------
def _dtv_kernel(a_ref, b_ref, ma_ref, sa_ref, mb_ref, sb_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    p = jnp.exp(a - ma_ref[...]) / sa_ref[...]
    q = jnp.exp(b - mb_ref[...]) / sb_ref[...]
    out_ref[...] += 0.5 * jnp.sum(jnp.abs(p - q), axis=-1, keepdims=True)


def dtv_pallas(a_logits: jnp.ndarray, b_logits: jnp.ndarray,
               interpret: bool = True) -> jnp.ndarray:
    """(R, V) x 2 -> (R,) TV distance. Caller pads R to BLK_R and V to
    BLK_V multiples (padding lanes use NEG logits -> zero probability)."""
    R, V = a_logits.shape
    ma, sa = softmax_stats(a_logits, interpret)
    mb, sb = softmax_stats(b_logits, interpret)
    grid = (R // BLK_R, V // BLK_V)
    row = pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0))
    out = pl.pallas_call(
        _dtv_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLK_R, BLK_V), lambda i, j: (i, j)),
                  pl.BlockSpec((BLK_R, BLK_V), lambda i, j: (i, j)),
                  row, row, row, row],
        out_specs=pl.BlockSpec((BLK_R, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), jnp.float32),
        interpret=interpret,
    )(a_logits, b_logits, ma, sa, mb, sb)
    return out[:, 0]
