"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# 1. DTV between softmax(a) and softmax(b) over a large vocab (paper Eq. 5)
# ---------------------------------------------------------------------------
def dtv_ref(a_logits: jnp.ndarray, b_logits: jnp.ndarray) -> jnp.ndarray:
    """(B, V), (B, V) -> (B,) total variation distance."""
    p = jax.nn.softmax(a_logits.astype(jnp.float32), axis=-1)
    q = jax.nn.softmax(b_logits.astype(jnp.float32), axis=-1)
    return 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)


def softmax_stats_ref(logits: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(R, V) -> (max (R,), sumexp (R,)) — the online-softmax statistics."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    s = jnp.sum(jnp.exp(x - m[:, None]), axis=-1)
    return m, s


# ---------------------------------------------------------------------------
# 2. Verification row stats: fused argmax + logsumexp + candidate gather
#    (the per-step hot spot of speculative verification: B·(W+1)·V work)
# ---------------------------------------------------------------------------
def verify_stats_ref(logits: jnp.ndarray, cand: jnp.ndarray):
    """logits: (R, V); cand: (R,) int32 token per row.

    Returns (argmax (R,), max (R,), sumexp (R,), cand_logit (R,)).
    From these the acceptance rule is O(R): greedy accept = argmax == cand;
    p(cand) = exp(cand_logit - max) / sumexp."""
    x = logits.astype(jnp.float32)
    am = jnp.argmax(x, axis=-1).astype(jnp.int32)
    m = jnp.max(x, axis=-1)
    s = jnp.sum(jnp.exp(x - m[:, None]), axis=-1)
    cl = jnp.take_along_axis(x, cand[:, None].astype(jnp.int32),
                             axis=-1)[:, 0]
    return am, m, s, cl


# ---------------------------------------------------------------------------
# 3. Masked single-token decode attention (paper Eq. 8 consumed in-kernel)
# ---------------------------------------------------------------------------
def masked_decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray,
                                v: jnp.ndarray, mask: jnp.ndarray,
                                scale: float | None = None) -> jnp.ndarray:
    """q: (B, H, D); k, v: (B, S, Hkv, D); mask: (B, S) validity.

    GQA: H = g * Hkv. Returns (B, H, D)."""
    B, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, g, D).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg,
                        k.astype(jnp.float32)) * scale
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, None, None, :], scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.any(mask, axis=-1)[:, None, None, None], p, 0.0)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# 4. Tree-block decode attention (per-query ancestor mask rows)
# ---------------------------------------------------------------------------
def masked_tree_attention_ref(q: jnp.ndarray, k: jnp.ndarray,
                              v: jnp.ndarray, mask: jnp.ndarray,
                              scale: float | None = None) -> jnp.ndarray:
    """q: (B, T, H, D); k, v: (B, S, Hkv, D); mask: (B, T, S) per-query.

    The T=1 case with ``mask[:, 0]`` equals masked_decode_attention_ref."""
    B, T, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, T, Hkv, g, D).astype(jnp.float32)
    scores = jnp.einsum("bthgd,bshd->bthgs", qg,
                        k.astype(jnp.float32)) * scale
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, :, None, None, :], scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.any(mask, axis=-1)[:, :, None, None, None], p, 0.0)
    o = jnp.einsum("bthgs,bshd->bthgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# 4b. Paged decode attention (block-table gather + tree-block attention)
# ---------------------------------------------------------------------------
def paged_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, block_table: jnp.ndarray,
                        mask: jnp.ndarray,
                        scale: float | None = None) -> jnp.ndarray:
    """q: (B, T, H, D); k_pool, v_pool: (P, bs, Hkv, D) block pools;
    block_table: (B, R) int32 (negative = unallocated, mask must be False
    there); mask: (B, T, S) with S = R * bs.

    Materializes each row's contiguous (B, S, Hkv, D) view via the block
    table, then runs the tree-attention oracle — the allclose target for
    ``paged_flash_decode_pallas`` (which performs the same gather
    block-by-block inside the pipeline instead)."""
    P, bs, Hkv, D = k_pool.shape
    B, R = block_table.shape
    S = R * bs
    s = jnp.arange(S, dtype=jnp.int32)
    pid = block_table[:, s // bs]                            # (B, S)
    flat = jnp.maximum(pid, 0) * bs + (s % bs)[None, :]
    kv = k_pool.reshape(P * bs, Hkv, D)[flat]                # (B, S, Hkv, D)
    vv = v_pool.reshape(P * bs, Hkv, D)[flat]
    return masked_tree_attention_ref(q, kv, vv, mask, scale=scale)


# ---------------------------------------------------------------------------
# 5. Row-wise top-k (greedy tree-draft expansion)
# ---------------------------------------------------------------------------
def topk_ref(logits: jnp.ndarray, k: int):
    """(R, V) -> (values (R, k), indices (R, k)); ties resolve to the
    first maximal index, matching jnp.argmax (stable argsort)."""
    x = logits.astype(jnp.float32)
    order = jnp.argsort(-x, axis=-1, stable=True)[:, :k].astype(jnp.int32)
    vals = jnp.take_along_axis(x, order, axis=-1)
    return vals, order
