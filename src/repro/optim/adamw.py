"""AdamW in raw JAX (optax is not available in this environment).

Moment tensors inherit the parameter sharding (same axes metadata), so the
optimizer adds no new sharding rules — XLA keeps m/v co-located with params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray       # () int32
    m: Any                  # first moment (fp32)
    v: Any                  # second moment (fp32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.01,
                 max_grad_norm: float = 1.0) -> Tuple[Any, AdamWState]:
    grads, _ = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
