"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B scaled]."""
import jax.numpy as jnp

from ..models.config import ModelConfig

ARCH_ID = "qwen1.5-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
        head_dim=128, d_ff=6912, vocab_size=151936,
        qkv_bias=True, rope_theta=1_000_000.0,
        max_position=32768, dtype=jnp.bfloat16,
        source="[hf:Qwen/Qwen1.5-0.5B]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=257,
        qkv_bias=True, rope_theta=1_000_000.0,
        max_position=4096, dtype=jnp.float32, source="[smoke]")
