"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution; the ViT/patch-merger is
a STUB providing projected patch embeddings [arXiv:2409.12191]."""
import jax.numpy as jnp

from ..models.config import ModelConfig, VLMConfig

ARCH_ID = "qwen2-vl-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="vlm",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        head_dim=128, d_ff=8960, vocab_size=151936,
        qkv_bias=True, rope_theta=1_000_000.0,
        vlm=VLMConfig(num_patch_tokens=256, mrope_sections=(16, 24, 24)),
        max_position=32768, dtype=jnp.bfloat16,
        source="[arXiv:2409.12191]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="vlm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=257,
        qkv_bias=True,
        vlm=VLMConfig(num_patch_tokens=16, mrope_sections=(4, 6, 6)),
        max_position=4096, dtype=jnp.float32, source="[smoke]")
