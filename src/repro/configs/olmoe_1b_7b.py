"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060]."""
import jax.numpy as jnp

from ..models.config import ModelConfig, MoEConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=0, vocab_size=50304,
        moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024,
                      capacity_factor=1.25),
        max_position=32768, dtype=jnp.bfloat16,
        source="[arXiv:2409.02060]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=0, vocab_size=257,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      capacity_factor=1.25),
        max_position=4096, dtype=jnp.float32, source="[smoke]")
