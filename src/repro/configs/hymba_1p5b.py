"""hymba-1.5b [hybrid] — parallel attention + mamba heads, ssm_state=16,
SWA everywhere except first/middle/last layers [arXiv:2411.13676]."""
import jax.numpy as jnp

from ..models.config import ModelConfig, SSMConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        head_dim=64, d_ff=5504, vocab_size=32001,
        sliding_window=1024,
        ssm=SSMConfig(state_size=16, expand=2, conv_size=4),
        max_position=1 << 22, dtype=jnp.bfloat16,
        source="[arXiv:2411.13676]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="hybrid",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=257,
        sliding_window=8,
        ssm=SSMConfig(state_size=4, expand=2, conv_size=4),
        max_position=4096, dtype=jnp.float32, source="[smoke]")
