"""whisper-tiny [audio] — enc-dec; conv/mel frontend is a STUB providing
frame embeddings; this config is the DECODER backbone [arXiv:2212.04356]."""
import jax.numpy as jnp

from ..models.config import EncDecConfig, ModelConfig

ARCH_ID = "whisper-tiny"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="audio",
        num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
        head_dim=64, d_ff=1536, vocab_size=51865,
        learned_positions=True, max_position=448,
        encdec=EncDecConfig(num_encoder_positions=1500, d_encoder=384),
        dtype=jnp.bfloat16, source="[arXiv:2212.04356]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="audio",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=257,
        learned_positions=True, max_position=448,
        encdec=EncDecConfig(num_encoder_positions=32, d_encoder=128),
        dtype=jnp.float32, source="[smoke]")
