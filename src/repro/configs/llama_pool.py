"""The paper's own evaluation pool (§5 Models): Llama-family variants with a
shared tokenizer — llama-68m, tinyllama-1.1b, llama-2-7b(-chat) — plus
scaled-down "demo" versions trainable on this CPU host for the end-to-end
SpecRouter serving examples and Table-2 benchmark.

The *demo* pool keeps the paper's capability ORDERING and rough size ratios
while being small enough to train a few hundred steps on CPU so that model
distributions genuinely correlate (random-init models have ~0 acceptance,
which would make speculation trivially useless)."""
import jax.numpy as jnp

from ..models.config import ModelConfig

ARCH_ID = "llama-pool"


def full_pool():
    """Paper-scale configs (dry-run / documentation only on this host)."""
    base = dict(arch_type="dense", rope_theta=10_000.0, dtype=jnp.bfloat16,
                max_position=4096, source="[paper §5 Models]")
    return [
        ModelConfig(name="llama-68m", num_layers=2, d_model=768,
                    num_heads=12, num_kv_heads=12, d_ff=3072,
                    vocab_size=32000, **base),
        ModelConfig(name="tinyllama-1.1b", num_layers=22, d_model=2048,
                    num_heads=32, num_kv_heads=4, d_ff=5632,
                    vocab_size=32000, **base),
        ModelConfig(name="llama-2-7b", num_layers=32, d_model=4096,
                    num_heads=32, num_kv_heads=32, d_ff=11008,
                    vocab_size=32000, **base),
        ModelConfig(name="llama-2-13b", num_layers=40, d_model=5120,
                    num_heads=40, num_kv_heads=40, d_ff=13824,
                    vocab_size=32000, **base),
    ]


def demo_pool(vocab_size: int = 512):
    """CPU-trainable pool with the same capability ordering as the paper's
    68m : 1.1b : 7b roles.  The wall-clock cost ratio c = T_draft/T_target
    must be genuinely small for speculation to pay off (paper §2.2), so the
    target is sized ~60× the draft in FLOPs — on this CPU that yields
    c ≈ 0.1, comparable to the paper's llama-68m : llama-2-7b pairing."""
    base = dict(arch_type="dense", rope_theta=10_000.0, dtype=jnp.float32,
                max_position=2048, source="[paper §5, demo-scaled]")
    return [
        ModelConfig(name="demo-68m", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=4, d_ff=256,
                    vocab_size=vocab_size, **base),
        ModelConfig(name="demo-1b", num_layers=5, d_model=160,
                    num_heads=4, num_kv_heads=4, d_ff=640,
                    vocab_size=vocab_size, **base),
        ModelConfig(name="demo-7b", num_layers=12, d_model=384,
                    num_heads=8, num_kv_heads=8, d_ff=1536,
                    vocab_size=vocab_size, **base),
    ]


def config() -> ModelConfig:
    return full_pool()[2]   # llama-2-7b: the paper's target model


def smoke_config() -> ModelConfig:
    return demo_pool()[0]
