"""minitron-8b [dense] — pruned nemotron [arXiv:2407.14679]."""
import jax.numpy as jnp

from ..models.config import ModelConfig

ARCH_ID = "minitron-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=256000,
        rope_theta=10_000.0, tie_embeddings=False,
        max_position=32768, dtype=jnp.bfloat16,
        source="[arXiv:2407.14679]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=257, tie_embeddings=False,
        max_position=4096, dtype=jnp.float32, source="[smoke]")
