"""Architecture registry: ``--arch <id>`` resolution for launchers,
smoke tests, and the dry-run matrix."""
from __future__ import annotations

from typing import Dict, List

from ..models.config import INPUT_SHAPES, InputShape, ModelConfig
from . import (gemma3_27b, granite_20b, hymba_1p5b, kimi_k2_1t_a32b,
               llama_pool, minitron_8b, olmoe_1b_7b, qwen1p5_4b, qwen2_vl_2b,
               whisper_tiny, xlstm_1p3b)

_MODULES = {
    "gemma3-27b": gemma3_27b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "xlstm-1.3b": xlstm_1p3b,
    "hymba-1.5b": hymba_1p5b,
    "qwen1.5-4b": qwen1p5_4b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "whisper-tiny": whisper_tiny,
    "minitron-8b": minitron_8b,
    "granite-20b": granite_20b,
    "qwen2-vl-2b": qwen2_vl_2b,
    "llama-pool": llama_pool,
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "llama-pool"]


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke_config()


def list_archs() -> List[str]:
    return list(_MODULES)


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """DESIGN §5 skips: long_500k only for sub-quadratic-capable archs;
    decode shapes run on every decoder-bearing arch (all 10)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context()
    return True


def effective_shape(cfg: ModelConfig, shape: InputShape):
    """(seq_len, batch, clipped): whisper's learned position table bounds
    its sequence length at 448 — 32k shapes run CLIPPED to the arch's
    architectural maximum (recorded in EXPERIMENTS.md §Dry-run)."""
    if cfg.learned_positions and shape.seq_len > cfg.max_position:
        return cfg.max_position, shape.global_batch, True
    return shape.seq_len, shape.global_batch, False


def dryrun_matrix():
    """All (arch, shape) baseline combos, with applicability filtering."""
    out = []
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        for s in INPUT_SHAPES.values():
            out.append((a, s.name, shape_applicable(cfg, s)))
    return out
