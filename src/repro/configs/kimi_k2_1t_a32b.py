"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + shared
expert [arXiv:2501.kimi2]."""
import jax.numpy as jnp

from ..models.config import ModelConfig, MoEConfig

ARCH_ID = "kimi-k2-1t-a32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        head_dim=112, d_ff=0, vocab_size=163840,
        moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048,
                      capacity_factor=1.25, num_shared_experts=1,
                      d_shared=2048),
        max_position=131072, dtype=jnp.bfloat16,
        source="[arXiv:2501.kimi2]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=0, vocab_size=257,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      capacity_factor=1.25, num_shared_experts=1,
                      d_shared=64),
        max_position=4096, dtype=jnp.float32, source="[smoke]")
