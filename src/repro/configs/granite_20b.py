"""granite-20b [dense] — llama-arch code model, MQA (kv=1)
[arXiv:2405.04324]."""
import jax.numpy as jnp

from ..models.config import ModelConfig

ARCH_ID = "granite-20b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
        head_dim=128, d_ff=24576, vocab_size=49152,
        rope_theta=10_000.0,
        max_position=8192, dtype=jnp.bfloat16,
        source="[arXiv:2405.04324]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
        head_dim=32, d_ff=256, vocab_size=257,
        max_position=4096, dtype=jnp.float32, source="[smoke]")
