"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, xLSTM[7:1] interleave
[arXiv:2405.04517]."""
import jax.numpy as jnp

from ..models.config import ModelConfig, SSMConfig

ARCH_ID = "xlstm-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        ssm=SSMConfig(slstm_every=8, mlstm_proj_factor=2.0,
                      slstm_proj_factor=1.334),
        max_position=1 << 22, dtype=jnp.bfloat16,
        source="[arXiv:2405.04517]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="ssm",
        num_layers=4, d_model=128, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=257,
        ssm=SSMConfig(slstm_every=2, mlstm_proj_factor=2.0),
        max_position=4096, dtype=jnp.float32, source="[smoke]")
