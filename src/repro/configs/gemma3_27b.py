"""gemma3-27b [dense] — 5:1 local:global sliding-window attention, 128k
context [hf:google/gemma-3-1b-pt scaled per assignment]."""
import jax.numpy as jnp

from ..models.config import ModelConfig

ARCH_ID = "gemma3-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
        head_dim=168, d_ff=21504, vocab_size=262144,
        sliding_window=1024, local_global_ratio=5,
        rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        qk_norm=True, sandwich_norm=True, embed_scale=True,
        logit_softcap=30.0, attn_softcap=50.0,
        max_position=131072, dtype=jnp.bfloat16,
        source="[hf:google/gemma-3-1b-pt]")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=257,
        sliding_window=8, local_global_ratio=1,
        rope_theta=10_000.0, rope_theta_global=1_000_000.0,
        qk_norm=True, sandwich_norm=True, embed_scale=True,
        logit_softcap=30.0, attn_softcap=50.0,
        max_position=4096, dtype=jnp.float32, source="[smoke]")
