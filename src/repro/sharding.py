"""Logical-axis sharding rules with divisibility fallback (DESIGN §6).

Every model init returns an ``axes`` pytree mirroring its params, with
tuples of logical axis names per dimension.  ``build_sharding`` maps each
logical axis onto mesh axes by RULES, degrading to replication whenever the
tensor dim does not divide the mesh axis size — this is what lets every
(arch × shape × mesh) combination lower (qwen1.5's 20 heads, whisper's
51865 vocab, kimi's 8 KV heads all simply stay replicated on that dim while
everything else shards).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Priority-ordered mesh-axis candidates per logical axis.  Each entry is a
# tuple of mesh axes to try to use TOGETHER (e.g. batch over pod AND data).
RULES: Dict[str, Sequence[Tuple[str, ...]]] = {
    "vocab":      (("model",),),
    "heads":      (("model",),),
    "kv_heads":   (("model",),),
    "mlp":        (("model",),),
    "expert_mlp": (tuple(),),            # experts already take the model axis
    "experts":    (("model",),),
    "ssm_inner":  (("model",),),
    "ssm_dk":     (("model",),),
    "embed":      (("pod", "data"), ("data",)),   # FSDP
    "enc_embed":  (tuple(),),
    "batch":      (("pod", "data"), ("data",)),
    "seq":        (tuple(),),            # overridden for long-context decode
    "enc_seq":    (tuple(),),
    "layers":     (tuple(),),
    "layers2":    (tuple(),),
    "head_dim":   (tuple(),),
    "conv":       (tuple(),),
    "ssm_state":  (tuple(),),
}


def _axis_assignment(logical: Optional[str], dim: int, mesh: Mesh,
                     used: set, rules: Dict) -> Optional[Tuple[str, ...]]:
    """Pick mesh axes for one tensor dim, honoring divisibility and not
    reusing a mesh axis already consumed by another dim of this tensor."""
    if logical is None or logical not in rules:
        return None
    for cand in rules[logical]:
        cand = tuple(a for a in cand if a in mesh.axis_names)
        if not cand or any(a in used for a in cand):
            continue
        size = int(np.prod([mesh.shape[a] for a in cand]))
        if size > 1 and dim % size == 0:
            used.update(cand)
            return cand
        # try single axes of a multi-axis candidate (e.g. just "data")
        for a in cand:
            if a not in used and mesh.shape[a] > 1 and dim % mesh.shape[a] == 0:
                used.add(a)
                return (a,)
    return None


# dims are ASSIGNED in this priority order (first match wins the mesh axis);
# "seq" is deliberately last: it only takes an axis nothing else could use
# (context-parallel fallback for unshardable head counts).
_PRIORITY = ("experts", "vocab", "heads", "kv_heads", "mlp", "ssm_inner",
             "ssm_dk", "embed", "batch", "seq")


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: Mesh, rules: Dict = RULES) -> P:
    used: set = set()
    order = sorted(
        range(len(axes)),
        key=lambda i: _PRIORITY.index(axes[i]) if axes[i] in _PRIORITY
        else len(_PRIORITY))
    assignment: Dict[int, Optional[Tuple[str, ...]]] = {}
    for i in order:
        assignment[i] = _axis_assignment(axes[i], shape[i], mesh, used, rules)
    parts = []
    for i in range(len(axes)):
        a = assignment[i]
        parts.append(a if a is None else (a[0] if len(a) == 1 else a))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def build_sharding(axes_tree: Any, shape_tree: Any, mesh: Mesh,
                   rules: Dict = RULES) -> Any:
    """axes_tree: pytree of per-dim logical-name tuples (leaves).
    shape_tree: matching pytree of arrays or ShapeDtypeStructs."""
    def one(ax, arr):
        if ax is None:
            return NamedSharding(mesh, P())
        ax = tuple(ax) + (None,) * (len(arr.shape) - len(ax))
        return NamedSharding(mesh, spec_for(ax[:len(arr.shape)], arr.shape,
                                            mesh, rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: x is None or (
                            isinstance(x, tuple)
                            and all(isinstance(e, (str, type(None)))
                                    for e in x)))


def shape_tree_of(tree: Any) -> Any:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def with_long_context_rules(rules: Dict = RULES) -> Dict:
    """long_500k (batch=1): shard the KV-cache sequence axis over data
    instead of the unshardable batch axis (context parallelism)."""
    r = dict(rules)
    r["seq"] = (("data", "model"), ("data",), ("model",))
    r["batch"] = (tuple(),)
    return r


def with_decode_rules(rules: Dict = RULES) -> Dict:
    """Serving shapes: the KV cache dominates memory; when kv_heads cannot
    take the model axis (e.g. qwen1.5's 20 heads, granite's MQA kv=1), fall
    back to sharding the cache SEQUENCE axis over whatever mesh axis is
    left (context parallelism — attention reduces over seq, XLA inserts the
    partial-softmax collectives)."""
    r = dict(rules)
    r["seq"] = (("model",),)
    return r
