"""Loop-aware roofline accounting from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, but a
62-layer scan executes it 62×.  This module parses the optimized HLO,
builds the computation call graph (while bodies, fusions, to_apply),
propagates trip-count multipliers, and derives:

  * flops            — 2·M·N·K summed over every dot/convolution,
                       trip-count weighted (per-device, post-SPMD shapes)
  * hbm_bytes        — static HBM-traffic estimate: Σ over non-fusion-
                       internal instructions of (operand + output) buffer
                       bytes (fusions internalize their temporaries)
  * collective_bytes — Σ output bytes per collective op, trip-weighted

Methodology note: this is a STATIC estimate — reads that actually hit VMEM
reuse are counted as HBM traffic, so ``hbm_bytes`` is an upper bound; dots
dominated by the MXU are exact.  Both limitations are uniform across
configurations, so Δ comparisons in §Perf are meaningful.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = ("parameter(", "get-tuple-element(", "tuple(", "constant(",
             "bitcast(", "after-all(", "partition-id(", "iota(")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_e, total_b


@dataclasses.dataclass
class Instr:
    name: str
    out_text: str      # text before the op name (shapes)
    op: str
    rest: str          # full remainder (operands + attrs)


_OP_RE = re.compile(
    r"^((?:\((?:[^()]*|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*)"
    r"([a-z][\w\-]*)\((.*)$")


def parse_hlo(text: str):
    """-> (computations: name -> [Instr], order)."""
    comps: Dict[str, List[Instr]] = {}
    cur = "__top__"
    comps[cur] = []
    header_re = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        hm = header_re.match(line)
        if hm and line.endswith("{"):
            cur = hm.group(1)
            comps.setdefault(cur, [])
            continue
        if line == "}":
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        om = _OP_RE.match(rhs)
        if not om:
            continue
        comps[cur].append(Instr(name, om.group(1), om.group(2), om.group(3)))
    return comps


def _multipliers(comps) -> Tuple[Dict[str, int], set]:
    """Propagate loop trip counts through the call graph.

    Returns (multiplier per computation, fusion-internal computation set).
    While bodies/conditions are TOP-LEVEL (their instruction I/O is real
    HBM traffic each iteration); computations entered via fusion ``calls=``
    or ``to_apply=`` are internal (temporaries live in VMEM/registers)."""
    # direct call edges with weights
    edges: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    entry_candidates = set(comps)
    internal: set = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                m = re.search(r"body=%?([\w.\-]+)", ins.rest)
                c = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                trip = 1
                if c and c.group(1) in comps:
                    consts = [int(x) for x in _CONST_RE.findall(
                        "\n".join(f"{i.op}({i.rest}"
                                  for i in comps[c.group(1)]))]
                    if consts:
                        trip = max(consts)
                if m and m.group(1) in comps:
                    edges[cname].append((m.group(1), max(trip, 1)))
                    entry_candidates.discard(m.group(1))
                if c and c.group(1) in comps:
                    edges[cname].append((c.group(1), max(trip, 1)))
                    entry_candidates.discard(c.group(1))
            else:
                for attr in _CALL_ATTR_RE.finditer(ins.rest):
                    for callee in re.split(r",\s*", attr.group(1)):
                        callee = callee.lstrip("%")
                        if callee in comps:
                            edges[cname].append((callee, 1))
                            entry_candidates.discard(callee)
                            if "calls=" in ins.rest or "to_apply=" in ins.rest:
                                internal.add(callee)

    mult: Dict[str, int] = {c: 0 for c in comps}

    def visit(c, m):
        if m <= mult.get(c, 0):
            return
        mult[c] = m
        for callee, w in edges.get(c, []):
            visit(callee, m * w)

    for c in entry_candidates:
        visit(c, 1)
    for c in comps:      # unreachable safety
        if mult[c] == 0:
            mult[c] = 1
    # internal-ness propagates down the call graph
    changed = True
    while changed:
        changed = False
        for c in list(internal):
            for callee, _ in edges.get(c, []):
                if callee not in internal:
                    internal.add(callee)
                    changed = True
    return mult, internal


def analyze(text: str) -> Dict[str, float]:
    comps = parse_hlo(text)
    mult, internal = _multipliers(comps)

    # symbol table: instruction name -> output bytes
    out_bytes: Dict[str, int] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            _, b = _shape_elems_bytes(ins.out_text)
            out_bytes[ins.name] = b

    flops = 0.0
    hbm = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    for cname, instrs in comps.items():
        m = mult[cname]
        for ins in instrs:
            # --- dot flops (counted even inside fusions) ---------------
            if ins.op in ("dot", "convolution"):
                flops += m * _dot_flops(ins, out_bytes, comps)
            # --- collectives -------------------------------------------
            for cop in _COLLECTIVES:
                if ins.op.startswith(cop) and not ins.op.endswith("-done"):
                    _, b = _shape_elems_bytes(ins.out_text)
                    coll[cop] += m * b
            # --- HBM traffic (top-level only) --------------------------
            if cname not in internal:
                if ins.op in ("parameter", "get-tuple-element", "tuple",
                              "constant", "bitcast", "after-all",
                              "partition-id", "iota", "while", "call",
                              "conditional"):
                    continue
                _, ob = _shape_elems_bytes(ins.out_text)
                if ins.op in ("dynamic-slice", "slice", "gather"):
                    # reads only the sliced region (+ tiny indices)
                    hbm += m * 2 * ob
                    continue
                if ins.op == "dynamic-update-slice":
                    # in-place: reads + writes the UPDATE region only
                    opnames = re.findall(r"%([\w.\-]+)", ins.rest)
                    upd = out_bytes.get(opnames[1], ob) if len(opnames) > 1 \
                        else ob
                    hbm += m * 2 * upd
                    continue
                opbytes = [out_bytes.get(o, 0)
                           for o in re.findall(r"%([\w.\-]+)", ins.rest)]
                rb = sum(opbytes)
                if ins.op == "fusion" and opbytes:
                    callee = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                    callee_ops = {i.op for i in
                                  comps.get(callee.group(1), [])} \
                        if callee else set()
                    has_dus = "dynamic-update-slice" in callee_ops
                    has_ds = bool(callee_ops & {"dynamic-slice", "gather",
                                                "slice"})
                    if has_ds and not has_dus and ob < max(opbytes):
                        # slice-wrapping fusion: reads only the sliced
                        # region of its big operand, not the whole buffer
                        mx = max(opbytes)
                        t = 2 * ob + (rb - mx)
                        hbm += m * t
                        continue
                    if has_dus:
                        # update-in-place fusion: traffic is the update
                        # region (small operands), not the aliased buffer —
                        # whether the fusion's output is the slice or the
                        # whole carried buffer
                        mx = max(opbytes)
                        small = rb - mx
                        pos = [b for b in opbytes if b > 0 and b < mx]
                        floor = min(pos) if pos else ob
                        hbm += m * 2 * max(min(ob, small), min(floor, ob))
                        continue
                hbm += m * (ob + rb)

    coll_total = sum(coll.values())
    return {"flops": flops, "hbm_bytes": hbm,
            "collective_bytes": coll_total,
            "collectives": coll}


# dot flops need operand shapes; build a resolver on demand
_DOT_CACHE: Dict[int, Dict[str, str]] = {}


def _dot_flops(ins: Instr, out_bytes, comps) -> float:
    """2 * out_elems * contraction_size.

    Operand shapes resolve through the global def table (by element count
    and the contracting-dims attribute on the lhs)."""
    out_e, _ = _shape_elems_bytes(ins.out_text)
    # operand element counts
    key = id(comps)
    if key not in _DOT_CACHE:
        table = {}
        for instrs in comps.values():
            for i2 in instrs:
                e, _ = _shape_elems_bytes(i2.out_text)
                table[i2.name] = (e, i2.out_text)
        _DOT_CACHE.clear()           # keep one entry — bounded memory
        _DOT_CACHE[key] = table
    table = _DOT_CACHE[key]
    ops = re.findall(r"%([\w.\-]+)", ins.rest)
    if len(ops) < 2:
        return 0.0
    lhs_name = ops[0]
    lhs = table.get(lhs_name)
    if lhs is None:
        return 0.0
    lhs_e, lhs_text = lhs
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]+)\}", ins.rest)
    bm = re.search(r"lhs_batch_dims=\{([0-9,]*)\}", ins.rest)
    sm = _SHAPE_RE.search(lhs_text)
    if not (cm and sm):
        # convolution or unparsable: fall back to out*lhs/out heuristic
        return 2.0 * out_e * max(lhs_e // max(out_e, 1), 1)
    dims = [int(d) for d in sm.group(2).split(",") if d]
    kdims = [int(i) for i in cm.group(1).split(",") if i]
    k = 1
    for i in kdims:
        if i < len(dims):
            k *= dims[i]
    return 2.0 * out_e * k
