"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required because the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init,
while tests/benches must see the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s  (~per link)
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
