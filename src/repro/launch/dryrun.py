"""Multi-pod dry-run (deliverable e): prove every (architecture × input
shape × mesh) combination lowers AND compiles on the production meshes,
and harvest the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
      --shape decode_32k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Results are cached as JSON under benchmarks/dryrun_results/ (resumable).
"""
# The VERY FIRST lines — before ANY other import — because jax locks the
# device count on first init (system-prompt requirement).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import (ASSIGNED_ARCHS, INPUT_SHAPES, effective_shape,  # noqa: E402
                       get_config, shape_applicable)
from ..models.model import LanguageModel  # noqa: E402
from ..optim import adamw_init  # noqa: E402
from ..sharding import (RULES, build_sharding, spec_for,  # noqa: E402
                        with_decode_rules, with_long_context_rules)
from ..train import TrainState, make_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/dryrun_results")

_COLL_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the optimized HLO,
    multiplying collectives inside while-loop bodies (layer scans) by the
    loop trip count (max integer constant in the loop condition — the XLA
    idiom for counted scans)."""
    # split into computations
    comps = {}
    cur, buf = "__top__", []
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            comps[cur] = "\n".join(buf)
            cur, buf = m.group(1), []
        else:
            buf.append(line)
    comps[cur] = "\n".join(buf)

    # per-computation raw collective bytes
    per_comp = {}
    for name, text in comps.items():
        agg = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
               "all-to-all": 0, "collective-permute": 0}
        for m in _COLL_RE.finditer(text):
            if "-done(" in m.group(0):
                continue
            agg[m.group(2)] += _shape_bytes(m.group(1))
        per_comp[name] = agg

    # loop multipliers: body computation -> trip count
    mult = {name: 1 for name in comps}
    for m in _WHILE_RE.finditer(hlo_text):
        cond, body = m.group(1), m.group(2)
        consts = [int(c) for c in _CONST_RE.findall(comps.get(cond, ""))]
        if consts:
            mult[body] = max(mult.get(body, 1), max(consts))

    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    flat = dict(out)
    for name, agg in per_comp.items():
        for op, v in agg.items():
            out[op] += v * mult.get(name, 1)
            flat[op] += v
    out["total"] = sum(out[k] for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    flat["total"] = sum(flat[k] for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))
    out["unrolled_total"] = out["total"]
    out["flat_total"] = flat["total"]
    return out


# ---------------------------------------------------------------------------
def _batch_spec(mesh, batch, rules):
    return NamedSharding(mesh, spec_for(("batch", "seq"), (batch, 1 << 30),
                                        mesh, rules))


def build_case(arch: str, shape_name: str, mesh, multi_pod: bool,
               kv_quant: bool = False):
    """Returns (fn, arg_specs, in_shardings) ready to lower."""
    cfg = get_config(arch)
    if kv_quant:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, kv_quant=True)
    shape = INPUT_SHAPES[shape_name]
    seq_len, batch, clipped = effective_shape(cfg, shape)
    lm = LanguageModel(cfg)
    if shape_name == "long_500k":
        rules = with_long_context_rules(RULES)
    elif shape.kind == "decode":
        rules = with_decode_rules(RULES)
    else:
        rules = RULES

    params = lm.abstract_params()
    paxes = lm.param_axes()
    p_shard = build_sharding(paxes, params, mesh, rules)
    tok_sharding = NamedSharding(
        mesh, spec_for(("batch", "seq"), (batch, seq_len), mesh, rules))

    extras_specs = lm.extras_specs(batch)
    extras_shard = {k: NamedSharding(mesh, P())
                    for k in extras_specs}

    if shape.kind == "train":
        step = make_train_step(lm, remat=True)
        opt = jax.eval_shape(adamw_init, params)
        ts = TrainState(params=params, opt=opt)
        ts_shard = TrainState(
            params=p_shard,
            opt=jax.eval_shape(adamw_init, params).__class__(
                step=NamedSharding(mesh, P()),
                m=build_sharding(paxes, params, mesh, rules),
                v=build_sharding(paxes, params, mesh, rules)))
        tokens = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)

        if cfg.arch_type == "vlm":
            npatch = cfg.vlm.num_patch_tokens
            patch = jax.ShapeDtypeStruct((batch, npatch, cfg.d_model),
                                         cfg.dtype)

            def fn(ts, tokens, patch):
                def ext_step(ts, tokens):
                    # splice stub patch embeddings over the first Np slots
                    from ..models import transformer as tf
                    emb = tf._embed(ts.params, cfg, tokens)
                    emb = jnp.concatenate([patch, emb[:, npatch:]], axis=1)
                    return step(ts, tokens,
                                extras={"input_embeds": emb})
                return ext_step(ts, tokens)
            args = (ts, tokens, patch)
            shards = (ts_shard, tok_sharding, NamedSharding(mesh, P()))
        elif extras_specs:
            def fn(ts, tokens, enc):
                return step(ts, tokens, extras={"enc_states": enc})
            args = (ts, tokens) + tuple(extras_specs.values())
            shards = (ts_shard, tok_sharding) + tuple(extras_shard.values())
        else:
            fn = step
            args = (ts, tokens)
            shards = (ts_shard, tok_sharding)
        return fn, args, shards, cfg, dict(seq=seq_len, batch=batch,
                                           clipped=clipped)

    # inference shapes
    if shape.kind == "prefill":
        cap = seq_len
        state, st_axes = lm.abstract_state(batch, cap)
        st_shard = build_sharding(st_axes, state, mesh, rules)
        tokens = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)

        def fn(params, state, tokens, *extra):
            ex = dict(zip(extras_specs.keys(), extra))
            return lm.prefill(params, state, tokens, logits_mode="last",
                              **ex)
        args = (params, state, tokens) + tuple(extras_specs.values())
        shards = (p_shard, st_shard, tok_sharding) \
            + tuple(extras_shard.values())
        return fn, args, shards, cfg, dict(seq=seq_len, batch=batch,
                                           clipped=clipped)

    # decode: ONE new token against a seq_len KV cache (serve_step);
    # capacity rounded up to a 512 multiple so the seq axis stays shardable
    cap = ((seq_len + 4 + 511) // 512) * 512
    state, st_axes = lm.abstract_state(batch, cap)
    st_shard = build_sharding(st_axes, state, mesh, rules)
    tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    tok1_shard = NamedSharding(
        mesh, spec_for(("batch", None), (batch, 1), mesh, rules))

    def fn(params, state, tokens, *extra):
        ex = dict(zip(extras_specs.keys(), extra))
        return lm.decode(params, state, tokens, logits_mode="all", **ex)
    args = (params, state, tokens) + tuple(extras_specs.values())
    shards = (p_shard, st_shard, tok1_shard) + tuple(extras_shard.values())
    return fn, args, shards, cfg, dict(seq=seq_len, batch=batch,
                                       clipped=clipped)


def run_case(arch: str, shape_name: str, mesh_kind: str,
             outdir: str, force: bool = False, verbose: bool = True,
             kv_quant: bool = False):
    os.makedirs(outdir, exist_ok=True)
    out_path = os.path.join(outdir, f"{arch}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(out_path) and not force:
        if verbose:
            print(f"[skip cached] {out_path}")
        return json.load(open(out_path))
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "ok": False}
    if not shape_applicable(cfg, shape):
        rec.update(skipped=True,
                   reason="long_500k needs sub-quadratic attention "
                          "(DESIGN §5)")
        json.dump(rec, open(out_path, "w"), indent=1)
        print(f"[skip n/a] {arch} x {shape_name}")
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    t0 = time.perf_counter()
    try:
        fn, args, shards, cfg, meta = build_case(arch, shape_name, mesh,
                                                 multi, kv_quant=kv_quant)
        rec.update(meta)
        with mesh:
            jitted = jax.jit(fn, in_shardings=shards)
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        # loop-aware roofline accounting (cost_analysis counts while
        # bodies once — see hlo_analysis docstring)
        from . import hlo_analysis
        la = hlo_analysis.analyze(hlo)
        import gzip
        with gzip.open(out_path.replace(".json", ".hlo.txt.gz"), "wt") as f:
            f.write(hlo)
        rec.update(
            flops_loop_aware=la["flops"],
            hbm_bytes_loop_aware=la["hbm_bytes"],
            collective_bytes_loop_aware=la["collective_bytes"],
            collectives_by_op=la["collectives"],
        )
        rec.update(
            ok=True,
            devices=mesh.devices.size,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            flops=float(cost.get("flops", -1)),
            bytes_accessed=float(cost.get("bytes accessed", -1)),
            peak_bytes_per_device=int(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
            collectives=coll,
            param_count=cfg.param_count(),
            active_param_count=cfg.active_param_count(),
        )
        print(f"[ok] {arch} x {shape_name} x {mesh_kind}: "
              f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"coll={coll['total']:.3e} "
              f"peak/dev={rec['peak_bytes_per_device']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(error=str(e)[:2000], tb=traceback.format_exc()[-4000:])
        print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {e}")
    json.dump(rec, open(out_path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--outdir", default=RESULTS_DIR)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache variant (§Perf G2)")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cases = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape
        cases = [(args.arch, args.shape)]
    n_ok = n_fail = 0
    for a, s in cases:
        for mk in meshes:
            rec = run_case(a, s, mk, args.outdir, force=args.force,
                           kv_quant=args.kv_quant)
            if rec.get("ok") or rec.get("skipped"):
                n_ok += 1
            else:
                n_fail += 1
    print(f"done: {n_ok} ok/skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
