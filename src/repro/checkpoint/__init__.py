from .store import exists, load, save
