"""Pytree checkpointing without orbax: npz arrays + json treedef.

Layout:  <dir>/<name>.npz  (flat arrays, keys = flattened paths)
         <dir>/<name>.json (structure + dtypes + metadata)
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no bf16: store as f32
            arr = arr.astype(np.float32)   # (lossless: bf16 ⊂ f32)
        out[key] = arr
    return out


def save(path: str, tree: Any, metadata: Optional[Dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrs = _flatten(tree)
    np.savez(path + ".npz", **arrs)
    spec = jax.tree.map(lambda x: [list(np.shape(x)),
                                   str(np.asarray(x).dtype)], tree)
    with open(path + ".json", "w") as f:
        json.dump({"spec": jax.tree.map(lambda s: s, spec),
                   "metadata": metadata or {}}, f, default=str)


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a matching pytree)."""
    data = np.load(path + ".npz")
    arrs = _flatten(like)
    keys = list(arrs.keys())
    assert set(keys) == set(data.files), (
        f"checkpoint mismatch: {set(keys) ^ set(data.files)}")
    flat, treedef = jax.tree_util.tree_flatten(like)
    flat_keys, _ = jax.tree_util.tree_flatten_with_path(like)
    out = []
    import jax.numpy as jnp
    for (path_k, leaf) in flat_keys:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        assert tuple(arr.shape) == tuple(np.shape(leaf)), (
            f"checkpoint shape mismatch at {key}: "
            f"{arr.shape} vs {np.shape(leaf)}")
        want = np.asarray(leaf).dtype
        if want.name == "bfloat16":
            out.append(jnp.asarray(arr, dtype=jnp.bfloat16))
        else:
            out.append(np.asarray(arr, dtype=want))
    return jax.tree_util.tree_unflatten(treedef, out)


def exists(path: str) -> bool:
    return os.path.exists(path + ".npz") and os.path.exists(path + ".json")
