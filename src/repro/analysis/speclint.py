"""speclint CLI.

    python -m repro.analysis.speclint src/ tests/
    python -m repro.analysis.speclint src/ --tiers ast,meta,pallas
    python -m repro.analysis.speclint src/ tests/ --write-baseline

Exit codes: 0 clean, 1 findings, 2 internal error.

Tiers:
  ast     — source-level rules over every given .py file (fast)
  meta    — kernel/oracle/parity-test coverage (fast)
  pallas  — BlockSpec index-map bounds over full grids (seconds)
  jaxpr   — trace fused cycle + kernels.ops, primitive/donation checks
            (tens of seconds: jits a tiny pool)
  hlo     — compile the fused cycle, HLO + runtime one-transfer-per-cycle
            conformance (tens of seconds)

Dynamic-tier findings anchor to the entry point's file with line 0; they
cannot be inline-suppressed, only baselined.
"""
from __future__ import annotations

import argparse
import sys
import traceback
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from . import ast_rules, meta_rules
from .findings import Baseline, Finding, apply_suppressions, collect_suppressions

ALL_TIERS = ("ast", "meta", "pallas", "jaxpr", "hlo")

RULE_DOCS = {
    "host-sync": "host-sync hazards in hot-path modules (device_get, "
                 ".item(), np.asarray/float()/tracer-bool inside traced code)",
    "rng-literal-key": "PRNGKey(<literal>) in library code",
    "rng-key-reuse": "same PRNG key fed to multiple samplers without split",
    "broad-except": "bare/broad except in serving paths (core/, models/)",
    "mutable-default": "mutable default argument",
    "dataclass-pytree": "dataclass field hygiene (implicit Optional, "
                        "mutable defaults)",
    "kernel-no-oracle": "Pallas kernel without a jnp oracle in kernels/ref.py",
    "kernel-no-parity-test": "Pallas kernel oracle never referenced by a test",
    "pallas-oob": "BlockSpec index map escapes an operand over the grid",
    "pallas-spec-arity": "BlockSpec rank/arity mismatch",
    "pallas-driver-error": "bounds-check driver failed to run a launcher",
    "jaxpr-callback": "host callback/infeed/outfeed primitive in a traced "
                      "device program",
    "jaxpr-donation": "donated buffer cannot alias an output",
    "jaxpr-trace-error": "entry point failed to trace/lower",
    "hlo-collectives": "UNEXPLAINED collectives in the compiled fused cycle "
                       "(single-device/1x1 placement only; a sharded "
                       "placement expects them)",
    "hlo-host-transfer": "host transfer ops inside the compiled fused cycle",
    "hlo-compile-error": "fused cycle failed to compile",
    "runtime-transfer-per-cycle": "a fused cycle made != 1 host transfer "
                                  "(PR 5 contract)",
    "bad-suppression": "inline suppression without a written reason",
    "bad-baseline": "baseline entry without a written justification",
    "parse-error": "file does not parse",
}


def _gather_files(paths: List[str]) -> List[Path]:
    files: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    # dedupe, keep order
    seen = set()
    out = []
    for f in files:
        key = str(f)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def run_static_tiers(
    files: List[Path], tiers: Tuple[str, ...]
) -> Tuple[List[Finding], Dict[str, dict]]:
    """AST + meta tiers plus suppression scanning.  Returns (findings
    after inline suppression, suppression map)."""
    sources: List[Tuple[str, str]] = []
    suppressions: Dict[str, dict] = {}
    findings: List[Finding] = []
    for f in files:
        try:
            text = f.read_text()
        except OSError as e:
            findings.append(Finding(
                rule="parse-error", path=str(f), line=0,
                message=f"cannot read: {e}"))
            continue
        sources.append((str(f), text))
        by_line, bad = collect_suppressions(text, str(f))
        suppressions[str(f)] = by_line
        findings.extend(bad)

    if "ast" in tiers:
        findings.extend(ast_rules.run(sources))
    if "meta" in tiers:
        kernel_files = [(p, s) for p, s in sources
                        if "kernels/" in ast_rules._posix(p)
                        and Path(p).name != "ref.py"]
        ref_sources = [s for p, s in sources
                       if ast_rules._posix(p).endswith("kernels/ref.py")]
        test_files = [(p, s) for p, s in sources
                      if Path(p).name.startswith("test_")]
        if kernel_files:
            findings.extend(meta_rules.run(
                kernel_files, ref_sources[0] if ref_sources else None,
                test_files))
    return apply_suppressions(findings, suppressions), suppressions


def run_dynamic_tiers(tiers: Tuple[str, ...], out=sys.stderr,
                      mesh: Optional[str] = None) -> List[Finding]:
    findings: List[Finding] = []
    if "pallas" in tiers:
        from . import pallas_bounds
        findings.extend(pallas_bounds.run())
    cap = None
    if "jaxpr" in tiers or "hlo" in tiers:
        from . import harness
        where = f" on mesh {mesh}" if mesh else ""
        print(f"speclint: capturing fused cycle (jits a tiny pool{where})"
              "...", file=out)
        cap = harness.capture_fused_linear(mesh=mesh)
    if "jaxpr" in tiers:
        from . import jaxpr_rules
        findings.extend(jaxpr_rules.run(cap))
    if "hlo" in tiers:
        from . import hlo_rules
        findings.extend(hlo_rules.run(cap))
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="speclint",
        description="Static + jaxpr/HLO analysis of SpecRouter's hot-path "
                    "invariants.")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to scan (e.g. src/ tests/)")
    ap.add_argument("--tiers", default="all",
                    help="comma list of tiers to run: "
                         f"{','.join(ALL_TIERS)} (default: all)")
    ap.add_argument("--baseline", default="speclint-baseline.json",
                    help="baseline file of grandfathered findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit "
                         "(justifications must then be filled in by hand)")
    ap.add_argument("--mesh", default=None, metavar="DXM",
                    help="run the dynamic tiers on a PLACED pool (e.g. "
                         "2x4).  Collectives in the compiled fused cycle "
                         "are then expected, not findings; the one-host-"
                         "transfer-per-cycle contract is still enforced.  "
                         "Needs the devices to exist (export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N before "
                         "running).")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULE_DOCS):
            print(f"{rule:28s} {RULE_DOCS[rule]}")
        return 0

    if args.tiers.strip() == "all":
        tiers = ALL_TIERS
    else:
        tiers = tuple(t.strip() for t in args.tiers.split(",") if t.strip())
        unknown = [t for t in tiers if t not in ALL_TIERS]
        if unknown:
            print(f"speclint: unknown tiers {unknown}; valid: "
                  f"{','.join(ALL_TIERS)}", file=sys.stderr)
            return 2

    if not args.paths and any(t in tiers for t in ("ast", "meta")):
        print("speclint: no paths given (try: src/ tests/)", file=sys.stderr)
        return 2

    try:
        files = _gather_files(args.paths)
        findings, _ = run_static_tiers(files, tiers)
        findings.extend(run_dynamic_tiers(tiers, mesh=args.mesh))
    except KeyboardInterrupt:
        raise
    except Exception:
        traceback.print_exc()
        print("speclint: internal error (this is a speclint bug, not a "
              "finding)", file=sys.stderr)
        return 2

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        Baseline.write(baseline_path, findings)
        print(f"speclint: wrote {len(findings)} finding(s) to "
              f"{baseline_path}; fill in each entry's 'reason' before "
              "committing")
        return 0

    baseline = Baseline.load(baseline_path)
    findings.extend(baseline.validate())
    new, matched = baseline.filter(findings)
    for fp in baseline.stale(matched):
        entry = baseline.entries[fp]
        print(f"speclint: stale baseline entry {fp} "
              f"({entry.get('rule')} in {entry.get('path')}) — the finding "
              "is gone, remove the entry", file=sys.stderr)

    for f in sorted(new, key=lambda f: (f.path, f.line, f.rule)):
        print(f.format())
    n_base = len(matched)
    suffix = f" ({n_base} baselined)" if n_base else ""
    if new:
        print(f"speclint: {len(new)} finding(s){suffix}", file=sys.stderr)
        return 1
    print(f"speclint: clean{suffix} "
          f"[tiers: {','.join(t for t in ALL_TIERS if t in tiers)}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
