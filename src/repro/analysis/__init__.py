"""speclint — static analysis enforcing SpecRouter's hot-path invariants.

PRs 3-6 bought their wins by imposing contracts the code cannot see being
broken at runtime until a benchmark regresses: the one-host-transfer-per-
cycle contract of the fused executor, jit donation through
``StateManager.checkout/commit``, static shapes per (chain, window | tree)
group, and the no-``PRNGKey(<literal>)`` RNG discipline.  This package
checks them at lint time, before any benchmark runs, in three tiers:

  * AST tier (``ast_rules``, ``meta_rules``) — whole-tree source checks:
    host-sync hazards in hot-path modules, RNG-key discipline, broad
    ``except`` in serving paths, mutable-default / dataclass-pytree
    hygiene, and the kernel/oracle-parity meta rule.
  * jaxpr tier (``jaxpr_rules``) — traces the registered device-program
    entry points (fused cycle builders, kernel ``ops`` wrappers) and
    asserts no host-callback primitives sneak into the traced programs
    and that every donated buffer has a same-shaped output to alias.
  * HLO tier (``hlo_rules``, ``pallas_bounds``) — compiles the fused
    linear cycle and checks the optimized HLO (no collectives, no host
    transfer ops) plus a RUNTIME conformance pass that the one-transfer-
    per-cycle contract holds; and symbolically evaluates every Pallas
    kernel's BlockSpec index maps over its full grid against the operand
    shapes.

CLI:  ``python -m repro.analysis.speclint src/ tests/``
Inline suppression:  ``# speclint: disable=<rule> -- <required reason>``
Baseline: ``speclint-baseline.json`` at the repo root grandfathers
pre-existing findings (each entry needs a written justification).
"""
from .findings import Finding, Baseline, collect_suppressions  # noqa: F401
