"""HLO-tier companion: symbolic bounds check of Pallas BlockSpec index maps.

Pallas index maps return *block* indices; an index map that walks past an
operand's shape reads garbage (interpret mode) or faults (TPU).  Nothing
in tracing catches it — the maps are evaluated at run/lower time per grid
step.  This checker drives every registered kernel launcher with small
concrete operands, intercepts ``pallas_call`` to capture
(grid, in_specs, out_specs, out_shape, operands), then evaluates every
index map at every grid point and asserts

    0 <= index_map(idx)[d] * block[d]           (non-negative start)
    index_map(idx)[d] * block[d] + block[d] <= operand.shape[d]

for every dimension of every operand, including the scalar-prefetch block
table of the paged kernel (the map dereferences ``table[b*R + r]``, so
table *values* are exercised too).

Rule ids: ``pallas-oob`` (a map escapes an operand),
``pallas-spec-arity`` (block rank != operand rank).
"""
from __future__ import annotations

import contextlib
import inspect
import itertools
from pathlib import Path
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding


class _Record:
    def __init__(self, kernel_name: str, grid: Tuple[int, ...],
                 in_specs: Sequence[Any], out_specs: Sequence[Any],
                 out_shapes: Sequence[Any], num_scalar_prefetch: int):
        self.kernel_name = kernel_name
        self.grid = grid
        self.in_specs = list(in_specs)
        self.out_specs = list(out_specs)
        self.out_shapes = list(out_shapes)
        self.num_scalar_prefetch = num_scalar_prefetch
        self.operands: List[Any] = []


def _as_list(x: Any) -> List[Any]:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


@contextlib.contextmanager
def _capture_pallas_calls(records: List[_Record]):
    """Monkeypatch jax.experimental.pallas.pallas_call to record launch
    geometry and return zero outputs (skips actually running the kernel)."""
    from jax.experimental import pallas as pl

    real = pl.pallas_call

    def fake_pallas_call(kernel, *, grid=None, grid_spec=None, in_specs=None,
                         out_specs=None, out_shape=None, **kwargs):
        num_prefetch = 0
        if grid_spec is not None:
            grid = tuple(getattr(grid_spec, "grid", ()) or ())
            in_specs = _as_list(getattr(grid_spec, "in_specs", None))
            out_specs = _as_list(getattr(grid_spec, "out_specs", None))
            num_prefetch = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
        grid_t = tuple(grid) if grid is not None else ()
        name = getattr(kernel, "__name__", None) or getattr(
            getattr(kernel, "func", None), "__name__", "<kernel>")
        rec = _Record(name, grid_t, _as_list(in_specs), _as_list(out_specs),
                      _as_list(out_shape), num_prefetch)
        records.append(rec)

        def runner(*operands):
            rec.operands = list(operands)
            outs = [np.zeros(tuple(s.shape), dtype=s.dtype)
                    for s in rec.out_shapes]
            if out_shape is not None and not isinstance(out_shape, (list, tuple)):
                return outs[0]
            return outs

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        yield
    finally:
        pl.pallas_call = real


def _check_record(rec: _Record, anchor_path: str, anchor_line: int,
                  launcher: str) -> List[Finding]:
    findings: List[Finding] = []

    def emit(rule: str, message: str) -> None:
        findings.append(Finding(
            rule=rule, path=anchor_path, line=anchor_line,
            message=f"{launcher} [{rec.kernel_name}]: {message}",
            snippet=f"{launcher}:{rec.kernel_name}:{rule}:{message}",
        ))

    prefetch = rec.operands[: rec.num_scalar_prefetch]
    data_ops = rec.operands[rec.num_scalar_prefetch:]
    out_shapes = [tuple(s.shape) for s in rec.out_shapes]

    groups = [("in", rec.in_specs, [np.shape(o) for o in data_ops]),
              ("out", rec.out_specs, out_shapes)]
    for kind, specs, shapes in groups:
        if len(specs) != len(shapes):
            emit("pallas-spec-arity",
                 f"{len(specs)} {kind}_specs for {len(shapes)} operands")
            continue
        for op_i, (spec, shape) in enumerate(zip(specs, shapes)):
            block = tuple(getattr(spec, "block_shape", ()) or ())
            index_map = getattr(spec, "index_map", None)
            if index_map is None or not block:
                continue
            block = tuple(1 if b is None else int(b) for b in block)
            if len(block) != len(shape):
                emit("pallas-spec-arity",
                     f"{kind}[{op_i}] block rank {len(block)} != operand "
                     f"rank {len(shape)} (block {block}, shape {shape})")
                continue
            for idx in itertools.product(*(range(g) for g in rec.grid)):
                try:
                    bidx = index_map(*idx, *prefetch)
                except TypeError as e:
                    emit("pallas-spec-arity",
                         f"{kind}[{op_i}] index map rejects grid point "
                         f"{idx}: {e}")
                    break
                bidx = tuple(int(b) for b in _as_list(bidx))
                if len(bidx) != len(shape):
                    emit("pallas-spec-arity",
                         f"{kind}[{op_i}] index map returns {len(bidx)} "
                         f"indices for rank-{len(shape)} operand")
                    break
                bad_dim = None
                for d, (b, blk, extent) in enumerate(zip(bidx, block, shape)):
                    start = b * blk
                    if start < 0 or start + blk > extent:
                        bad_dim = (d, start, blk, extent)
                        break
                if bad_dim is not None:
                    d, start, blk, extent = bad_dim
                    emit("pallas-oob",
                         f"{kind}[{op_i}] dim {d}: grid point {idx} maps to "
                         f"[{start}, {start + blk}) outside extent {extent}")
                    break  # one finding per spec is enough
    return findings


def _anchor(fn: Callable) -> Tuple[str, int]:
    try:
        path = inspect.getsourcefile(fn) or "<kernels>"
        _, line = inspect.getsourcelines(fn)
    except (OSError, TypeError):
        path, line = "<kernels>", 0
    try:
        path = str(Path(path).resolve().relative_to(Path.cwd()))
    except ValueError:
        pass
    return path, line


def check_launch(launcher: Callable, *args: Any, **kwargs: Any) -> List[Finding]:
    """Run one launcher under capture and bounds-check every pallas_call
    it makes."""
    records: List[_Record] = []
    path, line = _anchor(launcher)
    name = getattr(launcher, "__name__", str(launcher))
    try:
        with _capture_pallas_calls(records):
            launcher(*args, **kwargs)
    except Exception as e:  # pragma: no cover - driver bug, not a finding
        return [Finding(
            rule="pallas-driver-error", path=path, line=line,
            message=f"could not drive {name}: {type(e).__name__}: {e}",
            snippet=f"{name}:driver",
        )]
    findings: List[Finding] = []
    for rec in records:
        findings.extend(_check_record(rec, path, line, name))
    return findings


def default_drives() -> List[Tuple[Callable, tuple, dict]]:
    """The repo's kernel launchers with small concrete shapes that cover
    multi-block grids (including the paged block-table dereference)."""
    from repro.kernels import attention as _attn
    from repro.kernels import dtv as _dtv
    from repro.kernels import verify as _verify

    rng = np.random.default_rng(0)
    B, H, Hkv, D = 2, 4, 2, 128
    S = 2 * _attn.BLK_S
    T = 4
    q1 = rng.standard_normal((B, H, D), dtype=np.float32)
    qT = rng.standard_normal((B, T, H, D), dtype=np.float32)
    k = rng.standard_normal((B, S, Hkv, D), dtype=np.float32)
    v = rng.standard_normal((B, S, Hkv, D), dtype=np.float32)
    mask1 = np.ones((B, S), dtype=bool)
    maskT = np.ones((B, T, S), dtype=bool)

    P, bs, R = 5, 8, 3
    kp = rng.standard_normal((P, bs, Hkv, D), dtype=np.float32)
    vp = rng.standard_normal((P, bs, Hkv, D), dtype=np.float32)
    table = rng.integers(0, P, size=(B, R)).astype(np.int32)
    maskP = np.ones((B, T, R * bs), dtype=bool)

    Rr, V = 2 * _verify.BLK_R, 2 * _verify.BLK_V
    logits = rng.standard_normal((Rr, V), dtype=np.float32)
    logits_b = rng.standard_normal((Rr, V), dtype=np.float32)
    cand = rng.integers(0, V, size=(Rr,)).astype(np.int32)

    return [
        (_attn.masked_decode_attention_pallas, (q1, k, v, mask1), {}),
        (_attn.masked_tree_attention_pallas, (qT, k, v, maskT), {}),
        (_attn.paged_flash_decode_pallas, (qT, kp, vp, table, maskP), {}),
        (_verify.verify_stats_pallas, (logits, cand), {}),
        (_verify.topk_pallas, (logits, 4), {}),
        (_dtv.softmax_stats, (logits,), {}),
        (_dtv.dtv_pallas, (logits, logits_b), {}),
    ]


def run(drives: Optional[List[Tuple[Callable, tuple, dict]]] = None
        ) -> List[Finding]:
    findings: List[Finding] = []
    for launcher, args, kwargs in (drives if drives is not None
                                   else default_drives()):
        findings.extend(check_launch(launcher, *args, **kwargs))
    return findings
