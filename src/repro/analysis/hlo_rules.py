"""Tier-3 HLO rules: inspect the COMPILED fused-cycle program and verify
the one-host-transfer-per-cycle contract end to end.

Static half — compile the captured fused linear body and reuse
``launch/hlo_analysis.py``:
  * ``hlo-collectives``    — a single-device fused cycle must contain no
    collective ops (one sneaking in means sharding annotations leaked
    into the serving path);
  * ``hlo-host-transfer``  — no infeed/outfeed/send/recv or host
    custom-calls inside the compiled program (transfers inside the
    program would not even show up in the profiler's host_sync counter).

Runtime half — drive a ``RouterSession`` on the tiny pool and, for each
fused cycle, count actual ``jax.device_get`` calls under
``jax.transfer_guard_device_to_host("disallow")`` (which turns any
*implicit* device→host transfer into an error while letting the one
sanctioned explicit FusedSummary transfer through):
  * ``runtime-transfer-per-cycle`` — a fused cycle performed != 1
    explicit transfer, or any implicit transfer at all.  This is the
    check that fails the build if the compiled fused linear cycle exceeds
    one host transfer per cycle.
"""
from __future__ import annotations

import contextlib
from typing import List, Optional

import jax
import numpy as np

from . import harness
from .findings import Finding

_EXECUTOR_PATH = "src/repro/core/executor.py"

HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "recv",
                     "send-done", "recv-done")
HOST_CUSTOM_CALL_MARKERS = ("MoveToHost", "MoveToDevice", "HostExecute",
                            "xla_ffi_host")


def check_compiled_program(cap: harness.FusedCapture) -> List[Finding]:
    from repro.launch import hlo_analysis

    findings: List[Finding] = []
    placement = getattr(cap, "placement", None)
    meshed = placement is not None and placement.size > 1
    mctx = (placement.mesh_context() if placement is not None
            else contextlib.nullcontext())
    jitted = jax.jit(cap.body, donate_argnums=harness.DONATE_ARGNUMS)
    try:
        with mctx:
            text = jitted.lower(*cap.arg_sds).compile().as_text()
    except Exception as e:
        return [Finding(
            rule="hlo-compile-error", path=_EXECUTOR_PATH, line=0,
            message=(f"could not compile fused body: "
                     f"{type(e).__name__}: {e}"),
            snippet="fused_linear:compile",
        )]

    stats = hlo_analysis.analyze(text)
    if stats["collective_bytes"] > 0 and not meshed:
        # On a multi-device placement collectives are EXPECTED — the
        # tensor-parallel verify and the level-boundary reshard lower to
        # them by design.  Only an UNEXPLAINED collective (one appearing
        # on a trivial/1x1 placement) is a finding.
        bad = {k: v for k, v in stats["collectives"].items() if v > 0}
        findings.append(Finding(
            rule="hlo-collectives", path=_EXECUTOR_PATH, line=0,
            message=(f"compiled fused linear cycle contains collectives "
                     f"{bad} on a single-device serving path"),
            snippet="fused_linear:collectives",
        ))

    comps = hlo_analysis.parse_hlo(text)
    hits = []
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op in HOST_TRANSFER_OPS:
                hits.append(f"{cname}:{ins.op}")
            elif ins.op == "custom-call" and any(
                    m in ins.rest for m in HOST_CUSTOM_CALL_MARKERS):
                hits.append(f"{cname}:custom-call(host)")
    if hits:
        findings.append(Finding(
            rule="hlo-host-transfer", path=_EXECUTOR_PATH, line=0,
            message=("compiled fused linear cycle contains host transfer "
                     f"ops: {sorted(set(hits))[:5]} — transfers inside "
                     "the program bypass the FusedSummary contract"),
            snippet="fused_linear:host-transfer",
        ))
    return findings


@contextlib.contextmanager
def _count_device_get():
    counter = {"n": 0}
    real = jax.device_get

    def counting(x):
        counter["n"] += 1
        return real(x)

    jax.device_get = counting
    try:
        yield counter
    finally:
        jax.device_get = real


def check_runtime_transfers(cap: Optional[harness.FusedCapture] = None,
                            cycles: int = 3) -> List[Finding]:
    """Per-cycle conformance on the real serving path: each fused cycle
    must perform exactly one explicit device→host transfer (the
    FusedSummary device_get) and zero implicit ones."""
    from repro.core.chain_router import RouterSession

    findings: List[Finding] = []
    pool = cap.pool if cap is not None else harness.tiny_pool()
    router_cls = type(cap.router) if cap is not None else None
    if router_cls is None:
        from repro.core import ChainRouter
        router_cls = ChainRouter
    chain = cap.chain if cap is not None else harness.DEFAULT_CHAIN
    router = router_cls(pool, chain[-1], greedy=True, adaptive=False,
                        fixed_chain=tuple(chain),
                        fixed_window=harness.DEFAULT_WINDOW, fused=True,
                        profile_every=10_000)
    sess = RouterSession(router, num_slots=2, max_len=96,
                         session_id="speclint")
    prompt = np.array(jax.random.randint(
        jax.random.PRNGKey(1), (2, 5), 0, 61))
    sess.admit(0, prompt[0], 64)
    sess.admit(1, prompt[1][:4], 64)
    sess.run_cycle()  # cycle 0 is the per-op profiling cycle (intentional
    #                   host syncs feed the scheduler); fused from cycle 1

    for i in range(cycles):
        if not sess.active.any():
            break
        syncs0 = router.profiler.counters.get("host_sync", 0)
        try:
            with _count_device_get() as dg, \
                    jax.transfer_guard_device_to_host("disallow"):
                sess.run_cycle()
        except Exception as e:
            findings.append(Finding(
                rule="runtime-transfer-per-cycle", path=_EXECUTOR_PATH,
                line=0,
                message=(f"fused cycle {i + 1} performed an implicit "
                         "device→host transfer (transfer guard tripped): "
                         f"{type(e).__name__}: {e}"),
                snippet=f"fused_cycle:implicit-transfer:{i}",
            ))
            break
        syncs = router.profiler.counters.get("host_sync", 0) - syncs0
        if dg["n"] != 1 or syncs != 1:
            findings.append(Finding(
                rule="runtime-transfer-per-cycle", path=_EXECUTOR_PATH,
                line=0,
                message=(f"fused cycle {i + 1}: expected exactly 1 host "
                         f"transfer, saw {dg['n']} device_get calls / "
                         f"{syncs} host_sync counts — the one-transfer-"
                         "per-cycle contract (PR 5) is broken"),
                snippet=f"fused_cycle:transfer-count:{dg['n']}:{syncs}",
            ))
            break
    return findings


def run(cap: Optional[harness.FusedCapture] = None) -> List[Finding]:
    if cap is None:
        try:
            cap = harness.capture_fused_linear()
        except Exception as e:
            return [Finding(
                rule="hlo-compile-error", path=_EXECUTOR_PATH, line=0,
                message=("could not capture the fused linear cycle: "
                         f"{type(e).__name__}: {e}"),
                snippet="fused_linear:capture",
            )]
    findings = check_compiled_program(cap)
    findings.extend(check_runtime_transfers(cap))
    return findings
