"""Finding/suppression/baseline core for speclint.

A finding is (rule, path, line, message).  Two escape hatches exist and
both require a written reason:

* inline: ``# speclint: disable=<rule>[,<rule>...] -- <reason>`` on the
  offending line, or on a comment line directly above it;
* baseline: an entry in ``speclint-baseline.json`` keyed by a fingerprint
  that is robust to line drift (rule + path + normalized source line).

A suppression without a reason is itself a finding (rule
``bad-suppression`` / ``bad-baseline``), so the escape hatch cannot rot
into a silent off switch.
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*speclint:\s*disable=([A-Za-z0-9_,\- ]+?)\s*(?:--\s*(.*))?$"
)


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str  # repo-relative posix path (or a synthetic anchor for dynamic tiers)
    line: int  # 1-based; 0 for whole-file / dynamic findings
    message: str
    snippet: str = ""  # normalized source line, used for fingerprinting

    def fingerprint(self) -> str:
        norm = " ".join(self.snippet.split()) if self.snippet else f"L{self.line}"
        digest = hashlib.sha1(
            f"{self.rule}|{self.path}|{norm}".encode("utf-8")
        ).hexdigest()
        return digest[:16]

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


@dataclass
class Suppression:
    rules: Tuple[str, ...]
    reason: str
    line: int  # line the comment sits on


def collect_suppressions(
    source: str, path: str
) -> Tuple[Dict[int, Suppression], List[Finding]]:
    """Scan ``source`` for inline suppression comments.

    Returns a map from *effective* line number -> Suppression, plus any
    findings for malformed suppressions (missing reason).  A suppression
    on a standalone comment line also covers the next non-comment line.
    """
    by_line: Dict[int, Suppression] = {}
    findings: List[Finding] = []
    lines = source.splitlines()
    for idx, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = (m.group(2) or "").strip()
        if not reason:
            findings.append(
                Finding(
                    rule="bad-suppression",
                    path=path,
                    line=idx,
                    message=(
                        "speclint suppression without a reason; write "
                        "'# speclint: disable=<rule> -- <why this is safe>'"
                    ),
                    snippet=text,
                )
            )
            continue
        sup = Suppression(rules=rules, reason=reason, line=idx)
        by_line[idx] = sup
        stripped = text.strip()
        if stripped.startswith("#"):
            # Standalone comment: extend coverage to the next code line.
            for nxt in range(idx + 1, len(lines) + 1):
                nxt_text = lines[nxt - 1].strip()
                if nxt_text and not nxt_text.startswith("#"):
                    by_line.setdefault(nxt, sup)
                    break
    return by_line, findings


def apply_suppressions(
    findings: Sequence[Finding], suppressions: Dict[str, Dict[int, Suppression]]
) -> List[Finding]:
    """Drop findings covered by an inline suppression for their rule."""
    kept: List[Finding] = []
    for f in findings:
        sup = suppressions.get(f.path, {}).get(f.line)
        if sup is not None and (f.rule in sup.rules or "all" in sup.rules):
            continue
        kept.append(f)
    return kept


@dataclass
class Baseline:
    """Checked-in grandfather list for pre-existing findings."""

    entries: Dict[str, dict] = field(default_factory=dict)  # fingerprint -> entry
    path: Optional[Path] = None

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls(path=path)
        data = json.loads(path.read_text())
        entries = {e["fingerprint"]: e for e in data.get("findings", [])}
        return cls(entries=entries, path=path)

    def validate(self) -> List[Finding]:
        """Baseline entries without a justification are findings themselves."""
        bad: List[Finding] = []
        for fp, entry in sorted(self.entries.items()):
            if not str(entry.get("reason", "")).strip():
                bad.append(
                    Finding(
                        rule="bad-baseline",
                        path=str(self.path) if self.path else "speclint-baseline.json",
                        line=0,
                        message=(
                            f"baseline entry {fp} ({entry.get('rule', '?')} in "
                            f"{entry.get('path', '?')}) has no written justification"
                        ),
                    )
                )
        return bad

    def filter(self, findings: Sequence[Finding]) -> Tuple[List[Finding], List[str]]:
        """Split findings into (new, matched-fingerprints)."""
        new: List[Finding] = []
        matched: List[str] = []
        for f in findings:
            fp = f.fingerprint()
            if fp in self.entries:
                matched.append(fp)
            else:
                new.append(f)
        return new, matched

    def stale(self, matched: Sequence[str]) -> List[str]:
        return sorted(set(self.entries) - set(matched))

    @staticmethod
    def write(path: Path, findings: Sequence[Finding]) -> None:
        payload = {
            "_comment": (
                "speclint grandfathered findings. Every entry must carry a "
                "written reason; remove entries as the findings are fixed."
            ),
            "findings": [
                {
                    "fingerprint": f.fingerprint(),
                    "rule": f.rule,
                    "path": f.path,
                    "message": f.message,
                    "reason": "",
                }
                for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
            ],
        }
        path.write_text(json.dumps(payload, indent=2) + "\n")
