"""Tier-1 AST rules: whole-tree source checks.

Rules and scopes (paths are matched as posix suffixes/substrings so the
same rules work on fixture trees in tests):

* ``host-sync``       — hot-path modules (``core/executor.py``,
  ``core/chain_router.py``, ``models/*``).  Module-wide: ``jax.device_get``
  and ``.item()`` (the per-op processors intentionally sync via
  ``np.asarray``/``block_until_ready`` and bill the profiler's
  ``host_sync`` counter, so those are only banned inside *traced* code).
  Inside traced scope additionally: ``np.asarray``/``np.array``,
  ``block_until_ready``, non-constant ``float()``/``int()``/``bool()``,
  and ``if``/``while`` conditions that call into ``jnp``/``jax``
  (tracer-bool → silent recompile or ConcretizationTypeError).
* ``rng-literal-key`` — library code: ``PRNGKey(<constant>)``.  Fresh
  entropy must flow in from the caller and through ``split`` (PR 5's
  ``_req_rng`` footgun).
* ``rng-key-reuse``   — library code: the same key variable fed to two or
  more samplers in one function without ever being ``split``/``fold_in``.
* ``broad-except``    — serving paths (``core/``, ``models/``): bare
  ``except``, ``except Exception``, ``except BaseException``.
* ``mutable-default`` — library code: mutable literal defaults on
  function parameters.
* ``dataclass-pytree`` — library code: dataclass fields with a ``None``
  default under a non-``Optional`` annotation (implicit Optional breaks
  pytree-leaf typing), or mutable literal defaults.
"""
from __future__ import annotations

import ast
from pathlib import PurePosixPath
from typing import Dict, Iterable, List, Sequence, Set

from .findings import Finding

HOT_PATH_SUFFIXES = ("core/executor.py", "core/chain_router.py")
HOT_PATH_DIRS = ("models/",)
SERVING_DIRS = ("core/", "models/", "serving/")
LIBRARY_EXCLUDE_DIRS = ("tests/", "benchmarks/", "analysis/", "scripts/")

# Call sites whose function-valued arguments are traced by JAX.
_TRACING_FUNCS = {
    "jit", "pallas_call", "scan", "while_loop", "fori_loop", "cond",
    "switch", "vmap", "pmap", "grad", "value_and_grad", "checkpoint",
    "remat", "make_jaxpr", "eval_shape", "associative_scan",
}
_SAMPLERS = {
    "categorical", "uniform", "normal", "bernoulli", "gumbel", "choice",
    "randint", "truncated_normal", "exponential", "laplace", "dirichlet",
}


def _posix(path: str) -> str:
    return str(PurePosixPath(path.replace("\\", "/")))


def is_hot_path(path: str) -> bool:
    p = _posix(path)
    return p.endswith(HOT_PATH_SUFFIXES) or any(
        f"/{d}" in p or p.startswith(d) for d in HOT_PATH_DIRS
    )


def is_serving(path: str) -> bool:
    p = _posix(path)
    return any(f"/{d}" in p or p.startswith(d) for d in SERVING_DIRS)


def is_library(path: str) -> bool:
    p = _posix(path)
    if not p.endswith(".py"):
        return False
    return not any(f"/{d}" in p or p.startswith(d) for d in LIBRARY_EXCLUDE_DIRS)


def _attr_chain(node: ast.AST) -> str:
    """Dotted name for Name/Attribute chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _tail(chain: str) -> str:
    return chain.rsplit(".", 1)[-1] if chain else ""


def _line(source_lines: Sequence[str], lineno: int) -> str:
    if 1 <= lineno <= len(source_lines):
        return source_lines[lineno - 1]
    return ""


def _collect_traced_functions(tree: ast.Module) -> Set[ast.AST]:
    """Function/lambda nodes whose bodies run under a JAX trace.

    Detected structurally, no name heuristics: decorated with ``jit`` (or
    ``partial(jit, ...)``), or passed by name / inline into a tracing call
    site (``jax.jit(body, ...)``, ``lax.scan(step, ...)``,
    ``pl.pallas_call(kernel, ...)``, ``partial(kernel, ...)`` inside one).
    """
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    traced: Set[ast.AST] = set()

    def _is_tracing_callee(func: ast.AST) -> bool:
        return _tail(_attr_chain(func)) in _TRACING_FUNCS

    def _mark_arg(arg: ast.AST) -> None:
        if isinstance(arg, ast.Name):
            for d in defs.get(arg.id, []):
                traced.add(d)
        elif isinstance(arg, ast.Lambda):
            traced.add(arg)
        elif isinstance(arg, ast.Call) and _tail(_attr_chain(arg.func)) == "partial":
            for sub in arg.args:
                _mark_arg(sub)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                chain = _attr_chain(dec)
                if _tail(chain) in ("jit", "pallas_call"):
                    traced.add(node)
                elif isinstance(dec, ast.Call):
                    dchain = _attr_chain(dec.func)
                    if _tail(dchain) in ("jit", "pallas_call"):
                        traced.add(node)
                    elif _tail(dchain) == "partial" and dec.args:
                        if _is_tracing_callee(dec.args[0]):
                            traced.add(node)
        elif isinstance(node, ast.Call) and _is_tracing_callee(node.func):
            for arg in node.args:
                _mark_arg(arg)
            for kw in node.keywords:
                if kw.arg in (None, "body_fun", "cond_fun", "f", "fun", "kernel"):
                    _mark_arg(kw.value)
    return traced


def _walk_own_scope(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested defs/lambdas
    (each nested def gets its own key-reuse pass)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _calls_into_jax(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = _attr_chain(sub.func)
            root = chain.split(".", 1)[0]
            if root in ("jnp", "jax", "lax"):
                return True
    return False


class _ModuleScan:
    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = _posix(path)
        self.lines = source.splitlines()
        self.tree = tree
        self.findings: List[Finding] = []

    def emit(self, rule: str, node: ast.AST, message: str) -> None:
        lineno = getattr(node, "lineno", 0)
        self.findings.append(
            Finding(
                rule=rule,
                path=self.path,
                line=lineno,
                message=message,
                snippet=_line(self.lines, lineno),
            )
        )

    # -- host-sync ---------------------------------------------------------

    def check_host_sync(self) -> None:
        if not is_hot_path(self.path):
            return
        traced = _collect_traced_functions(self.tree)
        traced_nodes: Set[ast.AST] = set()
        for fn in traced:
            traced_nodes.update(ast.walk(fn))

        for node in ast.walk(self.tree):
            in_traced = node in traced_nodes
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                tail = _tail(chain)
                if tail == "device_get":
                    self.emit(
                        "host-sync", node,
                        "jax.device_get in a hot-path module forces a "
                        "device→host sync; route results through the "
                        "FusedSummary transfer point",
                    )
                elif tail == "item" and isinstance(node.func, ast.Attribute):
                    self.emit(
                        "host-sync", node,
                        ".item() blocks on device compute; keep scalars "
                        "on device or batch them into the cycle summary",
                    )
                elif in_traced:
                    if chain in ("np.asarray", "np.array", "numpy.asarray",
                                 "numpy.array", "onp.asarray", "onp.array"):
                        self.emit(
                            "host-sync", node,
                            f"{chain} inside traced code materializes a "
                            "tracer on host; use jnp instead",
                        )
                    elif tail == "block_until_ready":
                        self.emit(
                            "host-sync", node,
                            "block_until_ready inside traced code is a "
                            "host sync hazard",
                        )
                    elif (
                        isinstance(node.func, ast.Name)
                        and node.func.id in ("float", "int", "bool")
                        and len(node.args) == 1
                        and not isinstance(node.args[0], ast.Constant)
                    ):
                        self.emit(
                            "host-sync", node,
                            f"{node.func.id}() on a traced value forces "
                            "concretization (host sync or trace error)",
                        )
            elif isinstance(node, (ast.If, ast.While)) and in_traced:
                if _calls_into_jax(node.test):
                    self.emit(
                        "host-sync", node,
                        "branching on a jnp/jax expression inside traced "
                        "code concretizes a tracer; use lax.cond/jnp.where",
                    )

    # -- RNG discipline ----------------------------------------------------

    def check_rng(self) -> None:
        if not is_library(self.path):
            return
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                if _tail(_attr_chain(node.func)) == "PRNGKey" and node.args:
                    if isinstance(node.args[0], ast.Constant):
                        self.emit(
                            "rng-literal-key", node,
                            "PRNGKey(<literal>) in library code: every call "
                            "site draws the same stream; take a key argument "
                            "and split it",
                        )
        # key reuse: same key Name fed to >= 2 samplers in one function,
        # never split/fold_in in that function.
        for fn in ast.walk(self.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            sampler_uses: Dict[str, List[ast.Call]] = {}
            split_names: Set[str] = set()
            for node in _walk_own_scope(fn):
                if not isinstance(node, ast.Call):
                    continue
                tail = _tail(_attr_chain(node.func))
                if tail in _SAMPLERS and node.args \
                        and isinstance(node.args[0], ast.Name):
                    sampler_uses.setdefault(node.args[0].id, []).append(node)
                elif tail in ("split", "fold_in") and node.args \
                        and isinstance(node.args[0], ast.Name):
                    split_names.add(node.args[0].id)
            for name, uses in sampler_uses.items():
                if len(uses) >= 2 and name not in split_names:
                    self.emit(
                        "rng-key-reuse", uses[1],
                        f"key '{name}' feeds {len(uses)} samplers in "
                        f"'{fn.name}' without a split; correlated draws",
                    )

    # -- broad except ------------------------------------------------------

    def check_broad_except(self) -> None:
        if not is_serving(self.path):
            return
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ExceptHandler):
                if node.type is None:
                    self.emit(
                        "broad-except", node,
                        "bare 'except:' in a serving path hides scheduler "
                        "and state-manager bugs; catch the expected types "
                        "or use try/finally for cleanup",
                    )
                else:
                    chain = _tail(_attr_chain(node.type))
                    if chain in ("Exception", "BaseException"):
                        self.emit(
                            "broad-except", node,
                            f"'except {chain}' in a serving path; catch the "
                            "expected types or use try/finally for cleanup",
                        )

    # -- defaults hygiene --------------------------------------------------

    def check_defaults(self) -> None:
        if not is_library(self.path):
            return
        dataclass_bodies: Set[ast.AST] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ClassDef):
                for dec in node.decorator_list:
                    chain = _attr_chain(dec if not isinstance(dec, ast.Call)
                                        else dec.func)
                    if _tail(chain) in ("dataclass", "register_dataclass"):
                        dataclass_bodies.update(node.body)

        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                args = node.args
                for default in list(args.defaults) + [
                    d for d in args.kw_defaults if d is not None
                ]:
                    if isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                        isinstance(default, ast.Call)
                        and isinstance(default.func, ast.Name)
                        and default.func.id in ("list", "dict", "set")
                    ):
                        self.emit(
                            "mutable-default", default,
                            "mutable default argument is shared across "
                            "calls; use None + initialize inside",
                        )
            elif isinstance(node, ast.AnnAssign) and node in dataclass_bodies:
                if node.value is None:
                    continue
                ann = ast.unparse(node.annotation)
                if isinstance(node.value, ast.Constant) \
                        and node.value.value is None:
                    if "Optional" not in ann and "None" not in ann \
                            and ann != "Any" and not ann.startswith("object"):
                        self.emit(
                            "dataclass-pytree", node,
                            f"dataclass field annotated '{ann}' defaults to "
                            "None (implicit Optional): pytree leaves change "
                            "type depending on construction; annotate "
                            f"Optional[{ann}]",
                        )
                elif isinstance(node.value, (ast.List, ast.Dict, ast.Set)):
                    self.emit(
                        "dataclass-pytree", node,
                        "mutable literal default on a dataclass field; use "
                        "field(default_factory=...)",
                    )


def run_file(path: str, source: str) -> List[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [
            Finding(
                rule="parse-error",
                path=_posix(path),
                line=e.lineno or 0,
                message=f"cannot parse: {e.msg}",
            )
        ]
    scan = _ModuleScan(path, source, tree)
    scan.check_host_sync()
    scan.check_rng()
    scan.check_broad_except()
    scan.check_defaults()
    return scan.findings


def run(files: Iterable) -> List[Finding]:
    """files: iterable of (path, source) pairs or Path objects."""
    findings: List[Finding] = []
    for item in files:
        if isinstance(item, tuple):
            path, source = item
        else:
            path, source = str(item), item.read_text()
        findings.extend(run_file(path, source))
    return findings
