"""Tier-2 jaxpr rules: trace the registered device-program entry points
and inspect the traced programs themselves.

Entry points:
  * the fused linear cycle body (``Executor._build_fused_linear`` via the
    real serving path — see ``harness.capture_fused_linear``), and
  * every public ``kernels.ops`` wrapper.

Checks:
  * ``jaxpr-callback`` — no host-callback / infeed / outfeed primitives
    anywhere in the traced program (a stray ``jax.debug.print`` or
    ``io_callback`` inside the fused cycle would reintroduce a host hop
    per cycle and silently break PR 5's contract);
  * ``jaxpr-donation`` — the fused program actually lowers with input-
    output aliasing for the donated argnums (states, seq, seq_len,
    active), and every donated leaf has a same-shape/dtype output to
    alias into.  Donation that cannot alias silently falls back to a
    copy: the cycle still runs, 2x the memory.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax

from . import harness
from .findings import Finding

FORBIDDEN_PRIM_SUBSTRINGS = ("callback", "infeed", "outfeed")

_EXECUTOR_PATH = "src/repro/core/executor.py"
_OPS_PATH = "src/repro/kernels/ops.py"


def iter_all_eqns(jaxpr) -> List[Any]:
    """Flatten a (closed) jaxpr and every sub-jaxpr reachable through eqn
    params (pjit bodies, scan/while/cond branches, pallas kernels)."""
    core_jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    out = []
    stack = [core_jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        for eqn in getattr(j, "eqns", ()):
            out.append(eqn)
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    stack.append(sub)
    return out


def _sub_jaxprs(v: Any) -> List[Any]:
    subs = []
    if hasattr(v, "eqns"):
        subs.append(v)
    elif hasattr(v, "jaxpr"):
        subs.append(v.jaxpr)
    elif isinstance(v, (list, tuple)):
        for item in v:
            subs.extend(_sub_jaxprs(item))
    return subs


def forbidden_primitives(jaxpr) -> List[str]:
    hits = []
    for eqn in iter_all_eqns(jaxpr):
        name = eqn.primitive.name
        if any(s in name for s in FORBIDDEN_PRIM_SUBSTRINGS):
            hits.append(name)
    return hits


def check_entry_point(name: str, fn: Callable, args: Sequence[Any],
                      anchor_path: str) -> List[Finding]:
    findings: List[Finding] = []
    try:
        jaxpr = jax.make_jaxpr(fn)(*args)
    except Exception as e:
        return [Finding(
            rule="jaxpr-trace-error", path=anchor_path, line=0,
            message=f"could not trace {name}: {type(e).__name__}: {e}",
            snippet=f"{name}:trace",
        )]
    for prim in sorted(set(forbidden_primitives(jaxpr))):
        findings.append(Finding(
            rule="jaxpr-callback", path=anchor_path, line=0,
            message=(f"{name}: traced program contains host primitive "
                     f"'{prim}' — a host hop inside the device program"),
            snippet=f"{name}:{prim}",
        ))
    return findings


def _leaf_avals(tree: Any) -> List[Tuple[Tuple[int, ...], Any]]:
    return [(tuple(leaf.shape), jax.numpy.dtype(leaf.dtype))
            for leaf in jax.tree_util.tree_leaves(tree)]


def check_fused_donation(cap: harness.FusedCapture) -> List[Finding]:
    import contextlib

    findings: List[Finding] = []
    placement = getattr(cap, "placement", None)
    mctx = (placement.mesh_context() if placement is not None
            else contextlib.nullcontext())
    jitted = jax.jit(cap.body, donate_argnums=harness.DONATE_ARGNUMS)
    try:
        with mctx:
            text = jitted.lower(*cap.arg_sds).as_text()
    except Exception as e:
        return [Finding(
            rule="jaxpr-trace-error", path=_EXECUTOR_PATH, line=0,
            message=f"could not lower fused body: {type(e).__name__}: {e}",
            snippet="fused_linear:lower",
        )]
    if "tf.aliasing_output" not in text and "jax.buffer_donor" not in text:
        findings.append(Finding(
            rule="jaxpr-donation", path=_EXECUTOR_PATH, line=0,
            message=("fused linear program lowered WITHOUT input-output "
                     "aliasing despite donate_argnums — donated session "
                     "buffers are being copied, not reused"),
            snippet="fused_linear:no-aliasing",
        ))

    out_sds = jax.eval_shape(cap.body, *cap.arg_sds)
    out_avals = Counter(_leaf_avals(out_sds))
    for argnum in harness.DONATE_ARGNUMS:
        for shape, dtype in _leaf_avals(cap.arg_sds[argnum]):
            if out_avals[(shape, dtype)] > 0:
                out_avals[(shape, dtype)] -= 1
            else:
                findings.append(Finding(
                    rule="jaxpr-donation", path=_EXECUTOR_PATH, line=0,
                    message=(f"donated arg {argnum} leaf {dtype}{shape} "
                             "has no matching output to alias — that "
                             "buffer is freed, not reused (donation is a "
                             "no-op for it)"),
                    snippet=f"fused_linear:donate:{argnum}:{dtype}{shape}",
                ))
    return findings


def run(cap: Optional[harness.FusedCapture] = None) -> List[Finding]:
    findings: List[Finding] = []
    if cap is None:
        try:
            cap = harness.capture_fused_linear()
        except Exception as e:
            return [Finding(
                rule="jaxpr-trace-error", path=_EXECUTOR_PATH, line=0,
                message=("could not capture the fused linear cycle: "
                         f"{type(e).__name__}: {e}"),
                snippet="fused_linear:capture",
            )]
    import contextlib
    placement = getattr(cap, "placement", None)
    mctx = (placement.mesh_context() if placement is not None
            else contextlib.nullcontext())
    with mctx:
        findings.extend(check_entry_point(
            "fused_linear_cycle", cap.body, cap.arg_sds, _EXECUTOR_PATH))
    findings.extend(check_fused_donation(cap))
    for name, fn, args in harness.kernel_op_entry_points():
        findings.extend(check_entry_point(name, fn, args, _OPS_PATH))
    return findings
