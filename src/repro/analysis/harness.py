"""Shared harness for speclint's dynamic tiers (jaxpr / HLO).

Builds a deliberately tiny two-model pool (the fused program's *structure*
— transfer points, donation, primitives — is size-independent), runs a
fused generate through the real ``ChainRouter``/``Executor`` serving path,
and captures the un-jitted fused-cycle body plus the abstract shapes of
its first invocation.  Everything downstream (``jax.make_jaxpr``,
``jax.jit(...).lower()``) runs on those captures, so the checks see the
exact program production code would run for this chain group.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import numpy as np

DEFAULT_CHAIN = ("lintd", "lintt")
DEFAULT_WINDOW = 3
DONATE_ARGNUMS = (1, 2, 3, 6)  # states, seq, seq_len, active — executor contract


def tiny_pool(mesh=None):
    """Two dense models small enough that jit + a few cycles stay in
    seconds on CPU.  ``mesh`` ("dxm" spec / Mesh / Placement) places the
    pool: target tensor-parallel, draft replicated — the same
    ``auto_assign`` shape the serving engine's ``--mesh`` knob uses."""
    import jax.numpy as jnp

    from repro.core import ModelPool, Placement
    from repro.models import ModelConfig
    from repro.models.model import LanguageModel

    placement = (Placement.from_spec(mesh) if mesh is not None
                 else Placement.single())
    p = ModelPool(placement=placement)
    for (n, L, d, s) in [("lintd", 2, 32, 1), ("lintt", 2, 48, 2)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=61, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    if not placement.is_trivial and not placement.kinds:
        placement.auto_assign(p.capability(), "lintt")
    return p


@dataclasses.dataclass
class FusedCapture:
    body: Callable            # un-jitted fused-cycle body
    prog: Any                 # the jitted program the serving path ran
    arg_sds: Tuple[Any, ...]  # ShapeDtypeStruct pytree of the real args
    chain: Tuple[str, ...]
    router: Any               # the ChainRouter that drove the capture
    pool: Any
    placement: Any = None     # the pool's Placement (None == trivial)


def _to_sds(x: Any, keep_sharding: bool = False) -> Any:
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        sh = getattr(x, "sharding", None) if keep_sharding else None
        from jax.sharding import NamedSharding
        if isinstance(sh, NamedSharding):
            return jax.ShapeDtypeStruct(tuple(x.shape), x.dtype, sharding=sh)
        return jax.ShapeDtypeStruct(tuple(x.shape), x.dtype)
    return jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype)


def capture_fused_linear(
    chain: Tuple[str, ...] = DEFAULT_CHAIN,
    window: int = DEFAULT_WINDOW,
    budget: int = 10,
    mesh=None,
) -> FusedCapture:
    """Drive a fused linear generate on the tiny pool, capturing the fused
    body + concrete arg shapes on the first fused cycle.  With ``mesh``
    the pool is PLACED and the captured arg shapes carry the real
    NamedShardings, so downstream lowering reproduces the sharded
    program (collectives and all)."""
    from repro.core import ChainRouter
    from repro.core.executor import Executor

    pool = tiny_pool(mesh)
    meshed = not pool.placement.is_trivial
    captured: Dict[str, Any] = {}
    orig = Executor._fused_program

    def spy(self, chain_, window_, tree, greedy, temperature,
            prefix_width, eos):
        prog = orig(self, chain_, window_, tree, greedy, temperature,
                    prefix_width, eos)
        if tree is not None or "body" in captured:
            return prog
        lms = [self.pool.model(m) for m in chain_]
        body = self._build_fused_linear(
            lms, window_, greedy, temperature, prefix_width, eos,
            reshard=self.placement.reshard_between_levels())

        def wrapper(*args):
            if "arg_sds" not in captured:
                captured["arg_sds"] = jax.tree.map(
                    lambda x: _to_sds(x, keep_sharding=meshed), args)
                captured["body"] = body
                captured["prog"] = prog
                captured["chain"] = tuple(chain_)
            return prog(*args)

        return wrapper

    Executor._fused_program = spy
    try:
        prompt = np.array(jax.random.randint(
            jax.random.PRNGKey(0), (2, 5), 0, 61))
        plens = np.array([5, 4])
        router = ChainRouter(pool, chain[-1], greedy=True, adaptive=False,
                             fixed_chain=tuple(chain), fixed_window=window,
                             fused=True, profile_every=1000)
        router.generate(prompt, plens, budget, request_id="speclint")
    finally:
        Executor._fused_program = orig

    if "body" not in captured:
        raise RuntimeError(
            "fused capture failed: the router never entered the fused path "
            f"for chain {chain} (window {window})")
    return FusedCapture(body=captured["body"], prog=captured["prog"],
                        arg_sds=captured["arg_sds"],
                        chain=captured["chain"], router=router, pool=pool,
                        placement=pool.placement)


def kernel_op_entry_points() -> List[Tuple[str, Callable, Tuple[Any, ...]]]:
    """(name, callable, abstract args) for every public kernels.ops
    wrapper — the jaxpr tier traces these alongside the fused body."""
    import jax.numpy as jnp

    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    B, H, Hkv, D, S, T, V, R = 2, 4, 2, 16, 32, 3, 61, 8
    bs, Rb = 8, 4
    from repro.kernels import ops

    return [
        ("ops.dtv",
         lambda a, b: ops.dtv(a, b),
         (sds((R, V), f32), sds((R, V), f32))),
        ("ops.verify_row_stats",
         lambda l, c: ops.verify_row_stats(l, c),
         (sds((R, V), f32), sds((R,), i32))),
        ("ops.draft_topk",
         lambda l: ops.draft_topk(l, 4),
         (sds((R, V), f32),)),
        ("ops.masked_decode_attention",
         lambda q, k, v, m: ops.masked_decode_attention(q, k, v, m),
         (sds((B, H, D), f32), sds((B, S, Hkv, D), f32),
          sds((B, S, Hkv, D), f32), sds((B, S), jnp.bool_))),
        ("ops.masked_tree_attention",
         lambda q, k, v, m: ops.masked_tree_attention(q, k, v, m),
         (sds((B, T, H, D), f32), sds((B, S, Hkv, D), f32),
          sds((B, S, Hkv, D), f32), sds((B, T, S), jnp.bool_))),
        ("ops.paged_decode_attention",
         lambda q, kf, vf, t, m: ops.paged_decode_attention(
             q, kf, vf, t, m, block_size=bs),
         (sds((B, T, H, D), f32), sds((Rb * 2 * bs, Hkv, D), f32),
          sds((Rb * 2 * bs, Hkv, D), f32), sds((B, Rb), i32),
          sds((B, T, Rb * bs), jnp.bool_))),
    ]
