"""Meta rule: every Pallas kernel exports a jnp oracle and a parity test.

A "kernel launcher" is any function in ``kernels/*.py`` whose body calls
``pallas_call``.  For each launcher we require:

* an oracle function in ``kernels/ref.py`` — by convention
  ``<name>_ref`` with the ``_pallas`` suffix stripped (an alias table
  covers historically-named oracles), and
* at least one test module that references the oracle by name (the
  parity test that pins kernel output to the oracle).

Rule ids: ``kernel-no-oracle``, ``kernel-no-parity-test``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .ast_rules import _attr_chain, _tail
from .findings import Finding

# Launchers whose oracle does not follow the <base>_ref convention.
ORACLE_ALIASES: Dict[str, str] = {
    "paged_flash_decode_pallas": "paged_attention_ref",
}

# Helper/non-kernel functions in kernels/ that may call pallas_call but
# are not themselves public launchers (none today; extend as needed).
LAUNCHER_IGNORE: Tuple[str, ...] = ()


def _functions_calling_pallas(tree: ast.Module) -> List[ast.FunctionDef]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) \
                    and _tail(_attr_chain(sub.func)) == "pallas_call":
                out.append(node)
                break
    return out


def expected_oracle(launcher_name: str) -> str:
    if launcher_name in ORACLE_ALIASES:
        return ORACLE_ALIASES[launcher_name]
    base = launcher_name
    if base.endswith("_pallas"):
        base = base[: -len("_pallas")]
    return f"{base}_ref"


def run(
    kernel_files: Sequence[Tuple[str, str]],
    ref_source: Optional[str],
    test_files: Sequence[Tuple[str, str]],
) -> List[Finding]:
    """kernel_files / test_files: (path, source) pairs; ref_source: text of
    kernels/ref.py (None if missing)."""
    ref_names: set = set()
    if ref_source is not None:
        try:
            for node in ast.walk(ast.parse(ref_source)):
                if isinstance(node, ast.FunctionDef):
                    ref_names.add(node.name)
        except SyntaxError:
            pass

    findings: List[Finding] = []
    for path, source in kernel_files:
        if Path(path).name == "ref.py":
            continue
        try:
            tree = ast.parse(source)
        except SyntaxError:
            continue  # ast tier reports parse errors
        lines = source.splitlines()
        for fn in _functions_calling_pallas(tree):
            if fn.name in LAUNCHER_IGNORE or fn.name.startswith("__"):
                continue
            oracle = expected_oracle(fn.name)
            snippet = lines[fn.lineno - 1] if fn.lineno <= len(lines) else ""
            if oracle not in ref_names:
                findings.append(
                    Finding(
                        rule="kernel-no-oracle",
                        path=path,
                        line=fn.lineno,
                        message=(
                            f"Pallas launcher '{fn.name}' has no jnp oracle "
                            f"'{oracle}' in kernels/ref.py; every kernel "
                            "needs a reference implementation"
                        ),
                        snippet=snippet,
                    )
                )
                continue
            tested = any(oracle in test_src for _, test_src in test_files)
            if not tested:
                findings.append(
                    Finding(
                        rule="kernel-no-parity-test",
                        path=path,
                        line=fn.lineno,
                        message=(
                            f"Pallas launcher '{fn.name}' has oracle "
                            f"'{oracle}' but no test references it; add a "
                            "kernel-vs-oracle parity test"
                        ),
                        snippet=snippet,
                    )
                )
    return findings


def load_and_run(src_roots: Iterable[Path], test_roots: Iterable[Path]) -> List[Finding]:
    kernel_files: List[Tuple[str, str]] = []
    ref_source: Optional[str] = None
    for root in src_roots:
        for p in sorted(root.rglob("kernels/*.py")):
            text = p.read_text()
            if p.name == "ref.py":
                ref_source = text
            else:
                kernel_files.append((str(p), text))
    test_files: List[Tuple[str, str]] = []
    for root in test_roots:
        for p in sorted(root.rglob("test_*.py")):
            test_files.append((str(p), p.read_text()))
    if not kernel_files:
        return []
    return run(kernel_files, ref_source, test_files)
