"""ModelChainScheduler (paper §4.2, Algorithm 1, Eq. 7).

Continuously selects the chain [M_1, …, M_N = M_t] — plus the draft shape:
a linear window W or a token-tree branching profile — minimizing the
predicted effective latency per committed target token, from EMA-profiled
per-model times and SimScore-derived acceptance probabilities.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .profiler import PerformanceProfiler
from .similarity import (SimilarityStore, SlotSimilarity,
                         acceptance_from_sim)
from .token_tree import TokenTree


@dataclasses.dataclass(frozen=True)
class ChainChoice:
    chain: Tuple[str, ...]          # model names, draft first, target last
    window: int                     # W (tree depth when tree is set)
    predicted_t_eff: float          # seconds per committed target token
    table: Dict = dataclasses.field(default_factory=dict, compare=False)
    tree: Optional[TokenTree] = None  # None = linear window draft
    # goodput objective actually minimized (== predicted_t_eff on the
    # latency-only / no-SLO degenerate path)
    score: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class LoadSignal:
    """Engine-side load snapshot feeding the goodput-aware chain search:
    run-queue depth (arrived requests with no free slot), slot occupancy,
    and the profiler's cycle-latency EMA.  ``pressure`` collapses it to
    [0, 1]: zero whenever nothing queues (full-but-keeping-up engines
    should still speculate deep — all work serves admitted requests),
    rising toward 1 as the queue approaches/exceeds the slot pool while
    slots are busy (every second of cycle wall then delays a queued
    request's first token)."""
    queue_depth: int = 0        # arrived, not yet admitted
    occupancy: float = 0.0      # busy slots / total slots
    cycle_ema_s: float = 0.0    # PerformanceProfiler.cycle_time()
    num_slots: int = 1

    @property
    def pressure(self) -> float:
        if self.num_slots <= 0:
            return 0.0
        q = min(self.queue_depth / float(self.num_slots), 1.0)
        occ = min(max(self.occupancy, 0.0), 1.0)
        return q * occ


def expected_accepted(alpha: float, w: float) -> float:
    """E[accepted | window w, acceptance α] = Σ_{k=1..w} α^k  (paper §4.2,
    continuous in w so staged filters compose)."""
    if alpha <= 1e-9:
        return 0.0
    if alpha >= 1.0 - 1e-9:
        return w
    return alpha * (1.0 - alpha ** w) / (1.0 - alpha)


def expected_tree_accepted(alpha: float, branching: Sequence[int]) -> float:
    """E[accepted depth] for a top-b token tree under per-token acceptance
    α: a level offering b candidates passes w.p. 1 - (1-α)^b and levels
    compose, so E = Σ_d Π_{e<=d} (1 - (1-α)^{b_e}).  The branching-1 tree
    reduces exactly to ``expected_accepted(α, W)`` — the linear window is
    the degenerate tree."""
    if alpha <= 1e-9:
        return 0.0
    alpha = min(alpha, 1.0)
    surv, e = 1.0, 0.0
    for b in branching:
        surv *= 1.0 - (1.0 - alpha) ** int(b)
        e += surv
    return e


class ModelChainScheduler:
    """Implements Algorithm 1.

    Cost model (Eq. 7): for chain C = [M_1 … M_N], window W:
        numerator   = W·T_1(decode)  +  Σ_{j≥2} VerifyCost_j(block_j)
        denominator = E[target tokens committed per cycle]
    VerifyCost_j uses the *measured* verify wall time for that block length
    when available (more faithful to 'real-time performance profiling' than
    a fixed analytic form), falling back to T_j·(1 + ν·block) cold-start.
    A chain-switch penalty (catch-up prefill of newly-joining models,
    amortized) discourages thrashing — beyond-paper addition, DESIGN §8.
    """

    def __init__(self, model_names: Sequence[str], target: str,
                 profiler: PerformanceProfiler, sims: SimilarityStore,
                 capability: Dict[str, float],
                 max_chain_len: int = 4,
                 windows: Sequence[int] = (2, 3, 4, 6, 8),
                 tree_shapes: Sequence = (),
                 tree_capable: Optional[Dict[str, bool]] = None,
                 verify_overhead: float = 0.1,
                 switch_penalty_steps: float = 32.0,
                 default_decode_s: float = 0.05,
                 reuse_rtol: float = 0.02,
                 explore_sim: float = 0.8,
                 capability_exponent: float = 0.5,
                 slo_aware: bool = False,
                 load_beta: float = 8.0,
                 slo_miss_penalty: float = 4.0,
                 qualify: Optional[Callable[[str], str]] = None):
        assert target in model_names
        self.models = list(model_names)
        self.target = target
        self.profiler = profiler
        # placement-qualified profiling keys (Placement.qualify): the T_i
        # model is keyed by (model, mesh slice) — the same model placed on
        # a different slice reads a different EMA.  Identity by default
        # (trivial placement), so unplaced pools see unchanged keys.
        self.qualify = qualify if qualify is not None else (lambda m: m)
        self.sims = sims
        self.capability = capability  # e.g. param count — sorts the pool
        self.max_chain_len = max_chain_len
        self.windows = tuple(windows)
        # token-tree draft shapes joining the (chain, window) search space;
        # a shape is eligible only for chains of tree-capable models
        self.tree_shapes = tuple(TokenTree.parse(t) for t in tree_shapes)
        self.tree_capable = tree_capable or {}
        self.nu = verify_overhead
        self.switch_penalty_steps = switch_penalty_steps
        self.default_decode_s = default_decode_s
        # Eq. 7 re-evaluation gate: with reschedule_every=1 the full
        # (chain, window, tree) sweep runs EVERY cycle even though its only
        # inputs are slow-moving EMAs.  ``get_optimal_chain`` snapshots
        # those inputs and reuses the previous argmin until some input has
        # drifted by more than ``reuse_rtol`` (relative).  0 disables reuse.
        self.reuse_rtol = reuse_rtol
        # exploration default: lazy chain membership means unscheduled
        # model pairs are never probed, so a pessimistic unobserved
        # default would lock the pool into target-only forever.  Treat
        # never-observed pairs as optimistically similar — one real cycle
        # (or the admission probe) replaces the optimism with evidence.
        self.explore_sim = explore_sim
        # cold-start decode-time prior: T_m ∝ capability^exponent.  The
        # default 0.5 is conservative for same-architecture pools; pools
        # whose wall time scales ~linearly with parameters can set 1.0.
        self.capability_exponent = capability_exponent
        # --- goodput-aware objective (SLO-aware serving) ---------------
        # With ``slo_aware`` on AND a load signal set, the argmin target
        # becomes predicted SLO attainment instead of raw T_eff:
        #   score = T_eff + pressure·load_beta·cycle_cost
        #           [+ slo_miss_penalty·max(0, T_eff - tpot_slo)]
        # Cycle cost (Eq. 7's numerator) is what queued requests wait on
        # — admission happens between cycles — so under pressure the
        # search shrinks the speculation window / flattens trees / drops
        # to target-only, and with pressure 0 the objective is EXACTLY
        # T_eff (idle engines speculate as deep as today; the degenerate
        # path is pinned bit-identical by tests/test_slo_scheduling.py).
        self.slo_aware = slo_aware
        self.load_beta = load_beta
        self.slo_miss_penalty = slo_miss_penalty
        self._load: Optional[LoadSignal] = None
        # per-slot (ttft_slo_s, tpot_slo_s); None entries = no SLO
        self._slot_slo: Dict[str, Tuple[Optional[float],
                                        Optional[float]]] = {}
        self.eval_count = 0           # full sweeps actually executed
        self.reuse_count = 0          # calls served from the memo
        self._last_inputs: Optional[Dict] = None
        self._last_choice: Optional[ChainChoice] = None
        # per-slot routing state: slot-scoped similarity EMAs over the
        # global prior, plus one (choice, inputs-snapshot) memo per slot
        self.slot_sims = SlotSimilarity(sims)
        self._slot_choice: Dict[str, ChainChoice] = {}
        self._slot_inputs: Dict[str, Dict] = {}

    # ---- Step 1: candidate chains (Alg. 1 lines 2-3) -------------------
    def candidate_chains(self) -> List[Tuple[str, ...]]:
        others = sorted(
            (m for m in self.models if m != self.target),
            key=lambda m: self.capability[m])
        chains: List[Tuple[str, ...]] = [(self.target,)]
        for depth in range(1, self.max_chain_len):
            for combo in itertools.combinations(others, depth):
                # combo is capability-ascending -> draft first
                chains.append(tuple(combo) + (self.target,))
        return chains

    # ---- acceptance inputs ----------------------------------------------
    def pair_alpha(self, slot: Optional[str], a: str, b: str) -> float:
        """α for adjacent chain models (a drafts for b): the slot's own
        DTV EMA when observed, else the pool-wide prior, else the
        exploration default (never-observed pairs must stay schedulable
        under lazy membership — nothing else will ever measure them)."""
        s = self.slot_sims.sim_score(slot, a, b)
        return acceptance_from_sim(s if s is not None else self.explore_sim)

    def observe_slot(self, slot: str, a: str, b: str, dtv: float):
        """Per-slot similarity feedback: the admission probe over the
        slot's chain members and the slot's row of every verify pass."""
        self.slot_sims.update(slot, a, b, dtv)

    def release_slot(self, slot: str):
        """Drop a retired slot's view (EMAs + memo + SLO) — the next
        occupant of the physical slot must start from the shared prior."""
        self.slot_sims.release(slot)
        self._slot_choice.pop(slot, None)
        self._slot_inputs.pop(slot, None)
        self._slot_slo.pop(slot, None)

    # ---- load / SLO plumbing (goodput objective inputs) -----------------
    def set_load(self, load: Optional[LoadSignal]):
        """Engine-published load snapshot.  Part of the Eq. 7 inputs
        snapshot when the goodput objective is active, so a load step
        change invalidates every memoized choice (pinned by
        ``tests/test_slo_scheduling.py``)."""
        self._load = load

    def set_slot_slo(self, slot: str, ttft_slo_s: Optional[float] = None,
                     tpot_slo_s: Optional[float] = None):
        """Attach the admitted request's SLOs to its slot's chain search
        (cleared by ``release_slot``)."""
        if ttft_slo_s is None and tpot_slo_s is None:
            self._slot_slo.pop(slot, None)
        else:
            self._slot_slo[slot] = (ttft_slo_s, tpot_slo_s)

    def _goodput_active(self) -> bool:
        return self.slo_aware and self._load is not None

    # ---- Eq. 7 predictor ------------------------------------------------
    def predict_costs(self, chain: Sequence[str], window: int,
                      alphas: Optional[Sequence[float]] = None,
                      tree: Optional[TokenTree] = None,
                      slot: Optional[str] = None) -> Tuple[float, float]:
        """Eq. 7's two ingredients for one (chain, window | tree) option:
        ``(cycle_cost_s, committed)`` — predicted wall seconds per
        speculative cycle and expected target tokens committed by it.
        ``predict_t_eff`` is their ratio; the goodput objective also
        reads the raw cycle cost (queued requests wait on cycle
        boundaries, so cycle wall time IS their TTFT currency)."""
        prof = self.profiler
        T = {m: prof.decode_time(self.qualify(m), self._default_time(m))
             for m in chain}
        if len(chain) == 1:
            return T[chain[0]], 1.0
        if alphas is None:
            alphas = [self.pair_alpha(slot, chain[i], chain[i + 1])
                      for i in range(len(chain) - 1)]

        if tree is not None and not tree.is_linear:
            # tree cycle: D sequential draft levels, every level verifies
            # the whole N-node tree (pruning shrinks real work but the
            # predictor stays conservative), commit = E[tree depth] + 1.
            # Per-node acceptance through the pruning chain is approximated
            # as the product of the per-level α's (independence).
            D, N = tree.depth_levels, tree.num_nodes
            a_bar = 1.0
            for a in alphas:
                a_bar *= a
            cost = D * prof.level_time(self.qualify(chain[0]),
                                       tree.branching, T[chain[0]])
            for j in range(1, len(chain)):
                verify_default = T[chain[j]] * (1.0 + self.nu * N)
                cost += prof.verify_time(self.qualify(chain[j]), N + 1,
                                         verify_default)
            committed = expected_tree_accepted(a_bar, tree.branching) + 1.0
            return cost, committed

        lam = float(window)          # candidate length entering level j+1
        cost = window * T[chain[0]]  # W sequential draft steps
        committed = 0.0
        for j in range(1, len(chain)):
            block = lam
            verify_default = T[chain[j]] * (1.0 + self.nu * block)
            cost += prof.verify_time(self.qualify(chain[j]),
                                     int(round(block)) + 1,
                                     verify_default)
            acc = expected_accepted(alphas[j - 1], lam)
            if j < len(chain) - 1:
                lam = acc + 1.0      # accepted prefix + correction joins
            else:
                committed = acc + 1.0  # target: accepted + bonus
        return cost, committed

    def predict_t_eff(self, chain: Sequence[str], window: int,
                      alphas: Optional[Sequence[float]] = None,
                      tree: Optional[TokenTree] = None,
                      slot: Optional[str] = None) -> float:
        cost, committed = self.predict_costs(chain, window, alphas=alphas,
                                             tree=tree, slot=slot)
        return cost / max(committed, 1e-9)

    def score_choice(self, t_eff: float, cycle_cost_s: float,
                     slot: Optional[str] = None) -> float:
        """Goodput objective (SLO-aware serving): per-token latency plus a
        pressure-weighted cycle-wall penalty, plus a soft-infeasibility
        penalty for options predicted to blow the slot's TPOT SLO.  With
        the goodput objective inactive (no SLOs configured, or no load
        signal) this IS ``t_eff`` — today's latency-only argmin."""
        if not self._goodput_active():
            return t_eff
        p = self._load.pressure
        score = t_eff + p * self.load_beta * cycle_cost_s
        if slot is not None:
            tpot_slo = self._slot_slo.get(slot, (None, None))[1]
            if tpot_slo is not None and t_eff > tpot_slo:
                score += self.slo_miss_penalty * (t_eff - tpot_slo)
        return score

    def _default_time(self, m: str) -> float:
        # cold start: scale a nominal decode time by relative capability
        base = min(self.capability.values())
        return self.default_decode_s * (
            self.capability[m] / base) ** self.capability_exponent

    # ---- memoization: Eq. 7 inputs snapshot -----------------------------
    def _inputs_snapshot(self, slot: Optional[str] = None) -> Dict:
        """Every value ``predict_t_eff`` can read: per-(op, model[, block])
        profiler EMAs, the pairwise similarity table, and (per-slot
        scheduling) the slot's own similarity EMAs."""
        snap = {("sim",) + k: v for k, v in self.sims.table().items()}
        for k, e in self.profiler.emas.items():
            if k[0] in ("decode1", "decode_level", "verify", "prefill") \
                    and e.count:
                snap[("ema",) + k] = e.get()
        if slot is not None:
            for k, v in self.slot_sims.table(slot).items():
                snap[("slotsim",) + k] = v
        if self._goodput_active():
            # the goodput objective reads the load pressure and the
            # slot's TPOT SLO — both must sit inside the drift gate, or a
            # load step change would keep serving the stale memo
            snap[("load", "pressure")] = self._load.pressure
            if slot is not None:
                ttft, tpot = self._slot_slo.get(slot, (None, None))
                snap[("slo", "ttft")] = -1.0 if ttft is None else ttft
                snap[("slo", "tpot")] = -1.0 if tpot is None else tpot
        return snap

    def _inputs_drifted(self, snap: Dict, last: Optional[Dict]) -> bool:
        if last is None or snap.keys() != last.keys():
            return True
        for k, v in snap.items():
            old = last[k]
            if abs(v - old) > self.reuse_rtol * max(abs(old), 1e-12):
                return True
        return False

    # ---- Steps 2-3: select optimum (Alg. 1 lines 6-18) ------------------
    def get_optimal_chain(self, slot: Optional[str] = None) -> ChainChoice:
        """Argmin of Eq. 7 over (chain, window, tree).  With ``slot``
        (per-slot routing) the acceptance inputs come from that slot's
        view (its probe + verify EMAs over the global prior), the switch
        penalty is charged against the SLOT's previous chain, and the
        memo is slot-scoped; ``slot=None`` is the pool-global schedule."""
        snap = self._inputs_snapshot(slot)
        last_choice = (self._slot_choice.get(slot) if slot is not None
                       else self._last_choice)
        last_inputs = (self._slot_inputs.get(slot) if slot is not None
                       else self._last_inputs)
        if (self.reuse_rtol > 0 and last_choice is not None
                and not self._inputs_drifted(snap, last_inputs)):
            self.reuse_count += 1
            return last_choice
        self.eval_count += 1
        best = None
        table = {}
        # switch penalty anchor: the slot's own previous chain, falling
        # back to the global memo (a fresh slot joining the incumbent
        # chain is free; anything else prices its catch-up prefills)
        prev = last_choice.chain if last_choice else (
            self._last_choice.chain if self._last_choice else None)
        for chain in self.candidate_chains():
            options = [(w, None)
                       for w in (self.windows if len(chain) > 1 else (1,))]
            if (len(chain) > 1 and self.tree_shapes
                    and all(self.tree_capable.get(m, False) for m in chain)):
                options += [(tr.depth_levels, tr) for tr in self.tree_shapes]
            for w, tr in options:
                cost, committed = self.predict_costs(chain, w, tree=tr,
                                                     slot=slot)
                t = cost / max(committed, 1e-9)
                if prev is not None and chain != prev:
                    # amortized catch-up prefill for newly joining models
                    joiners = set(chain) - set(prev)
                    pen = sum(self.profiler.prefill_time(
                                  self.qualify(m),
                                  10 * self._default_time(m))
                              for m in joiners)
                    t = t + pen / self.switch_penalty_steps
                s = self.score_choice(t, cost, slot=slot)
                table[(chain, w, tr)] = s
                if best is None or s < best.score:
                    best = ChainChoice(chain, w, t, tree=tr, score=s)
        best = ChainChoice(best.chain, best.window, best.predicted_t_eff,
                           table, tree=best.tree, score=best.score)
        if slot is not None:
            self._slot_choice[slot] = best
            self._slot_inputs[slot] = snap
        else:
            self._last_choice = best
            self._last_inputs = snap
        return best
