"""ModelPool + DeviceManager (paper §4.5): heterogeneous model lifecycle
(registration, lazy init/loading, caching, GC) and device placement.

TPU adaptation (DESIGN §3): instead of the paper's whole-model-per-GPU
placement, each model carries a *sharding tree* for a common mesh; on this
CPU host placement degrades to the single device, while the dry-run path
uses the same axes metadata to build NamedShardings over the 16x16 / 2x16x16
production meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax

from ..models.config import ModelConfig
from ..models.model import LanguageModel


@dataclasses.dataclass
class PoolEntry:
    cfg: ModelConfig
    lm: LanguageModel
    params: Any = None
    param_axes: Any = None
    init_fn: Optional[Callable[[], Any]] = None  # lazy loader
    device: Any = None
    loaded: bool = False

    def param_bytes(self) -> int:
        if not self.loaded:
            return 0
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.params))


class DeviceManager:
    """Tracks devices and per-device memory estimates; offers CPU fallback
    (paper §4.7).  On this host there is one CPU device; the API mirrors the
    paper's multi-GPU placement so serving code is placement-agnostic."""

    def __init__(self):
        self.devices = list(jax.devices())
        self.usage = {d: 0 for d in self.devices}

    def place(self, nbytes: int):
        dev = min(self.devices, key=lambda d: self.usage[d])
        self.usage[dev] += nbytes
        return dev

    def free(self, device, nbytes: int):
        if device in self.usage:
            self.usage[device] = max(0, self.usage[device] - nbytes)


class ModelPool:
    def __init__(self):
        self._entries: Dict[str, PoolEntry] = {}
        self.device_manager = DeviceManager()

    def register(self, cfg: ModelConfig,
                 params: Any = None, param_axes: Any = None,
                 init_fn: Optional[Callable[[], Any]] = None):
        lm = LanguageModel(cfg)
        e = PoolEntry(cfg=cfg, lm=lm, params=params, param_axes=param_axes,
                      init_fn=init_fn, loaded=params is not None)
        self._entries[cfg.name] = e
        return e

    def names(self):
        return list(self._entries)

    def entry(self, name: str) -> PoolEntry:
        return self._entries[name]

    def model(self, name: str) -> LanguageModel:
        return self._entries[name].lm

    def cfg(self, name: str) -> ModelConfig:
        return self._entries[name].cfg

    def params(self, name: str):
        e = self._entries[name]
        if not e.loaded:
            assert e.init_fn is not None, f"{name}: no params and no init_fn"
            e.params, e.param_axes = e.init_fn()
            e.loaded = True
            e.device = self.device_manager.place(e.param_bytes())
        return e.params

    def unload(self, name: str):
        """GC a model's weights (keeps registration for lazy re-load)."""
        e = self._entries[name]
        if e.loaded and e.init_fn is not None:
            self.device_manager.free(e.device, e.param_bytes())
            e.params, e.loaded, e.device = None, False, None

    def capability(self) -> Dict[str, float]:
        """Capability ordering for Alg. 1 — analytic parameter count."""
        return {n: float(e.cfg.param_count())
                for n, e in self._entries.items()}
