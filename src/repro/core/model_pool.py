"""ModelPool (paper §4.5): heterogeneous model lifecycle (registration,
lazy init/loading, caching, GC) and mesh placement.

TPU adaptation (DESIGN §3): instead of the paper's whole-model-per-GPU
placement, the pool carries ONE ``Placement`` (core/placement.py) for a
shared mesh; ``ensure_loaded`` materializes a member's params under its
placement kind's NamedSharding tree (draft replicated, target
tensor-parallel by default) and takes an exact per-device memory charge
that ``unload`` reverses precisely.  The default trivial placement
degrades to the single local device — byte-identical to the
pre-placement pool — while the dry-run path and the ``--mesh`` serving
knob use the same axes metadata over real meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax

from ..models.config import ModelConfig
from ..models.model import LanguageModel
from .placement import Placement


@dataclasses.dataclass
class PoolEntry:
    cfg: ModelConfig
    lm: LanguageModel
    params: Any = None
    param_axes: Any = None
    init_fn: Optional[Callable[[], Any]] = None  # lazy loader
    loaded: bool = False
    placed: bool = False          # device_put under the placement + charged
    sharding: Any = None          # NamedSharding tree (None when trivial)

    def param_bytes(self) -> int:
        if not self.loaded:
            return 0
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.params))


class ModelPool:
    def __init__(self, placement: Optional[Placement] = None):
        self._entries: Dict[str, PoolEntry] = {}
        self.placement = placement or Placement.single()

    def set_placement(self, placement: Placement) -> None:
        """Swap the pool's placement BEFORE anything is placed (the
        serving engine's ``mesh=`` knob calls this between pool
        construction and router construction)."""
        if any(e.placed for e in self._entries.values()):
            raise RuntimeError(
                "set_placement after members were placed — construct the "
                "pool with the placement (or set it before first use)")
        self.placement = placement

    def register(self, cfg: ModelConfig,
                 params: Any = None, param_axes: Any = None,
                 init_fn: Optional[Callable[[], Any]] = None):
        lm = LanguageModel(cfg)
        e = PoolEntry(cfg=cfg, lm=lm, params=params, param_axes=param_axes,
                      init_fn=init_fn, loaded=params is not None)
        self._entries[cfg.name] = e
        return e

    def names(self):
        return list(self._entries)

    def entry(self, name: str) -> PoolEntry:
        return self._entries[name]

    def model(self, name: str) -> LanguageModel:
        return self._entries[name].lm

    def cfg(self, name: str) -> ModelConfig:
        return self._entries[name].cfg

    def ensure_loaded(self, name: str) -> PoolEntry:
        """Materialize a member: lazy-init its params if needed, then
        place them under the pool placement (device_put with the member's
        NamedSharding tree on a real mesh; no-op movement on the trivial
        placement) and take the exact memory charge.  Idempotent."""
        e = self._entries[name]
        if not e.loaded:
            assert e.init_fn is not None, f"{name}: no params and no init_fn"
            e.params, e.param_axes = e.init_fn()
            e.loaded = True
        if not e.placed:
            e.sharding = self.placement.param_sharding(
                name, e.param_axes, e.params, cfg=e.cfg)
            if e.sharding is not None:
                e.params = jax.device_put(e.params, e.sharding)
            self.placement.charge(name, e.params, e.sharding)
            e.placed = True
        return e

    def params(self, name: str):
        return self.ensure_loaded(name).params

    def unload(self, name: str):
        """GC a model's weights (keeps registration for lazy re-load) and
        discharge exactly the memory charge ``ensure_loaded`` took."""
        e = self._entries[name]
        if e.loaded and e.init_fn is not None:
            if e.placed:
                self.placement.discharge(name)
            e.params, e.loaded = None, False
            e.placed, e.sharding = False, None

    def capability(self) -> Dict[str, float]:
        """Capability ordering for Alg. 1 — analytic parameter count."""
        return {n: float(e.cfg.param_count())
                for n, e in self._entries.items()}
