"""Static token-tree shapes for tree-structured speculation (SpecInfer-style).

A ``TokenTree`` describes the *shape* of one speculative cycle's draft
tree: ``branching[d]`` children are drafted at depth ``d`` for every
parent at depth ``d-1`` (``branching[0]`` roots expand the last committed
token).  The shape is static per ``ChainChoice`` so every jitted program
specializes on it once.  Nodes are numbered level by level (BFS, parent-major), so the
``j``-th node at depth ``d`` is the ``(j % branching[d])``-th child of the
``(j // branching[d])``-th node at depth ``d-1``.

The linear speculation window is exactly the branching-factor-1 special
case: ``TokenTree.linear(W) == TokenTree((1,) * W)`` is a chain of ``W``
nodes, and every tree-mode code path degenerates to the linear one.

Derived static arrays (all numpy, converted to device constants inside the
jitted programs that consume them):

  parent   (N,)    parent node id, -1 for the roots (children of t_last)
  depth    (N,)    0-based node depth
  attend   (N, N)  ancestor-or-self mask: ``attend[i, j]`` iff node ``j``
                   is on the root path of node ``i`` (incl. ``i`` itself).
                   This is the mask the attention kernels consume for the
                   tree block (see ``layers.overlay_block_mask``).
  paths    (L, D)  node ids along each root->leaf path (L = #leaves)
  children (N+1, max_b)  children of each *logit row*: row 0 is the
                   verification bonus row (t_last -> roots), row i+1 holds
                   node i's children; -1 padded.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenTree:
    branching: Tuple[int, ...]

    def __post_init__(self):
        assert len(self.branching) >= 1, "tree needs at least one level"
        assert all(int(b) >= 1 for b in self.branching), self.branching
        object.__setattr__(self, "branching",
                           tuple(int(b) for b in self.branching))

    # ---- identity ------------------------------------------------------
    @staticmethod
    def linear(window: int) -> "TokenTree":
        return TokenTree((1,) * int(window))

    @property
    def is_linear(self) -> bool:
        return all(b == 1 for b in self.branching)

    @property
    def depth_levels(self) -> int:
        """Tree depth D — the longest commit a cycle can make (plus bonus)."""
        return len(self.branching)

    @property
    def level_sizes(self) -> Tuple[int, ...]:
        sizes, n = [], 1
        for b in self.branching:
            n *= b
            sizes.append(n)
        return tuple(sizes)

    @property
    def level_offsets(self) -> Tuple[int, ...]:
        offs, acc = [], 0
        for s in self.level_sizes:
            offs.append(acc)
            acc += s
        return tuple(offs)

    @property
    def num_nodes(self) -> int:
        return sum(self.level_sizes)

    @property
    def num_leaves(self) -> int:
        return self.level_sizes[-1]

    # ---- derived structure (cached via __dict__-free lru on id) --------
    def _build(self):
        sizes, offs = self.level_sizes, self.level_offsets
        N, D = self.num_nodes, self.depth_levels
        parent = np.full(N, -1, np.int32)
        depth = np.zeros(N, np.int32)
        for d in range(D):
            for j in range(sizes[d]):
                i = offs[d] + j
                depth[i] = d
                if d > 0:
                    parent[i] = offs[d - 1] + j // self.branching[d]
        attend = np.zeros((N, N), bool)
        for i in range(N):
            j = i
            while j >= 0:
                attend[i, j] = True
                j = int(parent[j])
        paths = np.zeros((sizes[-1], D), np.int32)
        for leaf_j in range(sizes[-1]):
            i = offs[-1] + leaf_j
            for d in range(D - 1, -1, -1):
                paths[leaf_j, d] = i
                i = int(parent[i])
        max_b = max(self.branching)
        children = np.full((N + 1, max_b), -1, np.int32)
        for i in range(N):
            p = int(parent[i]) + 1          # logit-row coordinates
            # children are filled in node order -> sibling-rank order
            for s in range(max_b):
                if children[p, s] < 0:
                    children[p, s] = i
                    break
        return parent, depth, attend, paths, children

    @property
    def parent(self) -> np.ndarray:
        return self._cached()[0]

    @property
    def depth(self) -> np.ndarray:
        return self._cached()[1]

    @property
    def attend(self) -> np.ndarray:
        return self._cached()[2]

    @property
    def paths(self) -> np.ndarray:
        return self._cached()[3]

    @property
    def children(self) -> np.ndarray:
        return self._cached()[4]

    def _cached(self):
        c = _STRUCT_CACHE.get(self.branching)
        if c is None:
            c = self._build()
            _STRUCT_CACHE[self.branching] = c
        return c

    # ---- convenience ---------------------------------------------------
    def level_nodes(self, d: int) -> np.ndarray:
        o = self.level_offsets[d]
        return np.arange(o, o + self.level_sizes[d], dtype=np.int32)

    def level_attend(self, d: int) -> np.ndarray:
        """Ancestor mask for drafting level ``d``: rows are the level's
        nodes, columns every node of depth <= d (the tree slots written so
        far plus the level itself)."""
        o, n = self.level_offsets[d], self.level_sizes[d]
        return self.attend[o:o + n, :o + n]

    def __str__(self) -> str:
        return "x".join(str(b) for b in self.branching)

    @staticmethod
    def parse(spec) -> "TokenTree":
        """'2x2x1' / '2,2,1' / (2, 2, 1) -> TokenTree((2, 2, 1))."""
        if isinstance(spec, TokenTree):
            return spec
        if isinstance(spec, (tuple, list)):
            return TokenTree(tuple(int(b) for b in spec))
        s = str(spec).replace(",", "x").replace("-", "x")
        return TokenTree(tuple(int(b) for b in s.split("x") if b))


_STRUCT_CACHE: dict = {}
