"""ChainRouter (paper §4.1): central coordination of the multi-level
speculative generation loop (Listing 1).

Per cycle:
  1. get the optimal chain + window from the ModelChainScheduler;
  2. DraftRequest to M_1 (with per-model gap catch-up prefix);
  3. VerifyRequest to M_2 … M_t, splicing corrected candidates between
     levels (§4.3);
  4. consensus rollback: model at level j rolls back to
     min(k_j, …, k_N) — the prefix of ITS cached candidate that survived
     every deeper verifier (the paper's 'rollback length … based on
     consensus');
  5. commit target-accepted tokens + bonus/correction, update termination.

State sync invariant: a model's cache holds exactly ``seq[:seq_len-1]`` for
each row once its gap is caught up; gaps (from consensus < k_N) are
re-fed as the masked prefix of its next block (DESIGN §4).

Slot-level continuous batching (paper §4 "asynchronous batch processing"):
the generation loop is exposed as a step/cycle API via ``RouterSession`` —
``admit`` (catch-up prefill of a request into a free slot), ``run_cycle``
(one speculative cycle over every active slot), ``retire`` (free a finished
slot without stalling live ones).  ``ChainRouter.generate`` is a bulk
wrapper over the same session machinery: admit all rows, cycle until every
row terminates.  Slots are batch rows of ONE per-model session state
(key ``model/session_id``), so admission/retirement is per-row state
surgery (Executor.insert / Executor.retire), not state re-creation.

Per-slot chain routing with LAZY chain membership (default): every slot
carries its own ``ChainChoice`` — the admission-time similarity probe and
the slot's per-row verify feedback drive ``get_optimal_chain(slot)`` with
the global Eq. 7 memo as the shared prior — and a slot materializes state
ONLY in the models of its assigned chain.  Admission therefore prefills
O(chain) models, not O(pool); retirement frees only those rows; a model
joining a slot's chain later catches up through the ``_insert_row`` path
(priced by the scheduler's switch penalty).  ``run_cycle`` groups active
slots by assigned (chain, window, tree) and runs one active-masked
sub-cycle per group, so every jitted shape stays static and greedy output
remains bit-exact to target-only decoding per slot regardless of
grouping.  ``slot_routing=False`` restores the legacy behaviour — one
global chain per cycle, every pool model prefilled at admission — as the
A/B baseline (``benchmarks/routing_ab.py``).

Device-resident cycles (default, ``fused=True``): each sub-cycle group
runs as ONE jitted program (``Executor.fused_cycle``) that keeps the
session buffers (seq / seq_len / active / budgets) and every chain
member's model state on device; only a small per-cycle ``FusedSummary``
(commit slab, accept counts, DTV rows, cache cursors) crosses to host in
one transfer, and the host mirror of ``seq``/``seq_len``/``active`` is
rebuilt from it exactly (``generated``/``retire`` read the mirror).
Because fusing hides per-op timings, every ``profile_every``-th cycle
(default 16, cycle 0 included) runs the legacy per-op path instead,
refreshing the scheduler's ``T_i`` EMAs; capacity pressure or an
oversized catch-up gap also falls back to the per-op path for that cycle
(it owns the defrag/re-prefill escapes).  ``fused=False`` keeps the
host-orchestrated loop everywhere — the bit-exact A/B baseline
(``benchmarks/cycle_overhead.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import time as _time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import verification as ver
from .executor import (DraftRequest, DraftTreeRequest, Executor,
                       FusedCycleRequest, InsertRequest, PrefillRequest,
                       ResolveTreeRequest, RollbackRequest, VerifyRequest,
                       VerifyTreeRequest)
from .model_pool import ModelPool
from .profiler import PerformanceProfiler
from .scheduler import ChainChoice, ModelChainScheduler
from .similarity import SimilarityStore, pairwise_dtv, pairwise_dtv_rows
from .state_manager import StateManager
from .token_tree import TokenTree


@dataclasses.dataclass
class GenerationResult:
    sequences: List[np.ndarray]      # per row: prompt + generated (trimmed)
    generated: List[np.ndarray]      # per row: generated only
    steps: int                       # speculative cycles executed
    committed_tokens: int
    chain_history: List[Tuple[Tuple[str, ...], int]]
    acceptance_lengths: List[float]  # mean accepted per cycle (diagnostics)
    prefill_wall_s: float = 0.0
    cycle_wall_s: List[float] = dataclasses.field(default_factory=list)
    commits_per_cycle: List[np.ndarray] = dataclasses.field(
        default_factory=list)     # (B,) per cycle


@dataclasses.dataclass
class CycleReport:
    """One speculative cycle of a RouterSession.  ``chain``/``window``
    describe the first sub-cycle group (the only group when all slots
    share a chain); ``groups`` lists every (chain, window, num_slots)
    sub-cycle the cycle ran."""
    commits: np.ndarray           # (B,) tokens committed per slot
    wall_s: float                 # measured cycle wall time
    chain: Tuple[str, ...]
    window: int
    acc_mean: float               # mean committed over pre-cycle active slots
    groups: List[Tuple[Tuple[str, ...], int, int]] = \
        dataclasses.field(default_factory=list)


class ChainRouter:
    def __init__(self, pool: ModelPool, target: str,
                 eos_token: int = -1,
                 greedy: bool = True,
                 temperature: float = 1.0,
                 adaptive: bool = True,
                 fixed_chain: Optional[Sequence[str]] = None,
                 fixed_window: Optional[int] = None,
                 windows: Sequence[int] = (2, 3, 4, 6),
                 max_chain_len: int = 3,
                 reschedule_every: int = 1,
                 tree_shapes: Sequence = (),
                 fixed_tree=None,
                 seed: int = 0,
                 paged: bool = True,
                 slot_routing: bool = True,
                 fused: bool = True,
                 profile_every: int = 16,
                 scheduler_kwargs: Optional[dict] = None,
                 profiler: Optional[PerformanceProfiler] = None):
        self.pool = pool
        self.target = target
        # device-resident cycles: run each sub-cycle group as one jitted
        # program, with periodic unfused profiling cycles every
        # ``profile_every`` steps (0 = never; when enabled, cycle 0 is a
        # profiling cycle so the scheduler starts with real per-op
        # timings).  ``fused=False`` keeps the host-orchestrated per-op
        # loop everywhere as the A/B baseline.
        self.fused = fused
        self.profile_every = int(profile_every)
        # per-slot chain routing + lazy chain membership (the default):
        # each slot is scheduled independently and holds state only in
        # its assigned chain's models.  ``slot_routing=False`` keeps the
        # legacy one-global-chain engine that prefills the WHOLE pool at
        # admission — the O(pool)-admission baseline for A/B.
        self.slot_routing = slot_routing
        # paged KV cache (per-slot block tables) is the default serving
        # state; ``paged=False`` keeps the legacy contiguous shared-pointer
        # state for A/B.  Archs without a per-position cache (SSM/hybrid)
        # fall back to contiguous automatically either way.
        self.paged = paged
        self.eos = eos_token
        self.greedy = greedy
        self.temperature = temperature
        self.adaptive = adaptive
        self.fixed_chain = tuple(fixed_chain) if fixed_chain else None
        if self.fixed_chain is not None:
            assert len(set(self.fixed_chain)) == len(self.fixed_chain), \
                "chains cannot repeat a model (states are keyed by name)"
            assert self.fixed_chain[-1] == target
        self.fixed_window = fixed_window
        # token-tree speculation (off unless shapes are configured): the
        # scheduler may pick a tree draft for tree-capable chains, or a
        # fixed_tree forces one.  branching-factor-1 shapes run through the
        # same tree code path and are bit-identical to linear greedy.
        tree_ok = {m: pool.cfg(m).supports_tree for m in pool.names()}
        self.tree_shapes = tuple(TokenTree.parse(t) for t in tree_shapes)
        self.fixed_tree = (TokenTree.parse(fixed_tree)
                           if fixed_tree is not None else None)
        if self.fixed_tree is not None:
            assert self.fixed_chain is not None, \
                "fixed_tree requires fixed_chain (give the adaptive " \
                "scheduler tree_shapes instead)"
            bad = [m for m in self.fixed_chain if not tree_ok[m]]
            assert not bad, f"models {bad} cannot decode token trees"
            assert len(self.fixed_chain) > 1, \
                "tree speculation needs a draft model in the chain"
        self.reschedule_every = reschedule_every
        self.profiler = profiler or PerformanceProfiler()
        # the pool's placement (Placement.single() unless the pool was
        # built with a mesh): threads the per-member NamedSharding trees
        # through the executor and makes every profiling/scheduler key
        # placement-qualified.  Trivial placement = identity everywhere.
        self.placement = pool.placement
        self.states = StateManager()
        self.executor = Executor(pool, self.states, self.profiler)
        self.sims = SimilarityStore()
        self.scheduler = ModelChainScheduler(
            pool.names(), target, self.profiler, self.sims,
            pool.capability(), max_chain_len=max_chain_len, windows=windows,
            tree_shapes=self.tree_shapes, tree_capable=tree_ok,
            qualify=self.placement.qualify,
            **(scheduler_kwargs or {}))
        self.rng = jax.random.PRNGKey(seed)
        # static gap-prefix width: one jit shape per (model, Tc).  Tree
        # cycles can leave laggard levels up to depth D behind, so D joins
        # the bound; max_block bounds the per-cycle appended block for
        # capacity sizing (a tree appends all N nodes in one cycle).
        trees = self.tree_shapes + ((self.fixed_tree,)
                                    if self.fixed_tree else ())
        depth_max = max((t.depth_levels for t in trees), default=0)
        self.gcap = max(max(windows), depth_max) + max_chain_len + 2
        self.max_block = max(max(windows),
                             max((t.num_nodes for t in trees), default=0))

    # ------------------------------------------------------------------
    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    def _prefill_model(self, m: str, request_id: str, seq: np.ndarray,
                       seq_len: np.ndarray, max_len: int,
                       rows: Optional[np.ndarray] = None):
        """(Re-)create model m's state holding seq[:seq_len-1] per row.
        ``rows`` (B,) restricts materialization to those slots (lazy chain
        membership) — other rows stay empty, zero-length, zero-block."""
        eff_len = (seq_len if rows is None
                   else np.where(np.asarray(rows, bool), seq_len, 0))
        S = max(int(eff_len.max()), 1)
        seq = seq[:, :S]
        B = seq.shape[0]
        idx = np.arange(S)[None, :]
        valid = idx < (eff_len - 1)[:, None]
        cfg = self.pool.cfg(m)
        extras = self.pool.model(m).extras_for(B)
        probs, _sid = self.executor.prefill(PrefillRequest(
            model=m, request_id=request_id, tokens=seq.astype(np.int32),
            valid=valid, max_len=max_len,
            with_snaps=cfg.arch_type in ("ssm", "hybrid"),
            paged=self.paged, extras=extras))
        return probs

    def _gap_prefix(self, m: str, request_id: str, seq, seq_len, active):
        """Build [pads…, gap tokens…, t_last] (B, w) + valid mask, with w
        the smallest width bucket covering the largest row gap (buckets keep
        the jit-shape count bounded while avoiding gcap-wide pad waste).

        Returns (None, None, gap) if a gap exceeds gcap (caller re-prefills).
        """
        B = seq.shape[0]
        sid = StateManager.key(m, request_id)
        cache_len = self.states.lengths(sid)          # (B,)
        gap = (seq_len - 1) - cache_len               # tokens missing
        gap = np.where(active, gap, 0)
        if gap.min() < 0 or gap.max() > self.gcap:
            return None, None, gap
        w = 1
        for bucket in (1, 2, 4, 8, self.gcap + 1):
            if bucket >= int(gap.max()) + 1:
                w = bucket
                break
        # vectorized right-aligned gather (hot decode path — the per-row
        # Python loop was O(B·w) interpreter work per model per cycle):
        # column c of row b holds seq[b, cache_len[b] + c - (w-1-gap[b])]
        # for the gap span, then t_last in the final column.
        cols = np.arange(w)[None, :]                       # (1, w)
        off = cols - (w - 1 - gap[:, None])                # idx into gap run
        gmask = (off >= 0) & (cols < w - 1)                # (B, w)
        src = np.where(gmask, cache_len[:, None] + off, 0)
        prefix = np.where(
            gmask, seq[np.arange(B)[:, None], src], 0).astype(np.int32)
        pvalid = gmask.copy()
        last = np.maximum(seq_len - 1, 0)
        prefix[:, -1] = np.where(active, seq[np.arange(B), last], 0)
        pvalid[:, -1] = active.astype(bool)
        return prefix, pvalid, gap

    def _ensure_capacity(self, m: str, request_id: str, needed: int,
                         seq, seq_len, max_len,
                         rows: Optional[np.ndarray] = None,
                         state_rows: Optional[np.ndarray] = None) -> None:
        """Guard against physical buffer exhaustion.  Paged states use
        BLOCK accounting: every row that will append (``rows`` mask; None =
        all — paged appends only consume capacity for writing rows, so the
        caller should scope the check to them) must fit ``needed`` more
        entries inside its per-row capacity and the pool must hold enough
        free blocks for the worst case — with default full provisioning
        this never trips, because retirement returns blocks instead of
        burning shared-pointer headroom (the churn regression test pins the
        counters at zero).  Contiguous states keep the legacy escalation:
        force-defragment masked holes, then rebuild from the committed
        stream as a last resort (their shared pointer advances for every
        row, so ``rows`` does not apply).  Without this, out-of-range
        appends would be CLAMPED (contiguous) or DROPPED (paged), silently
        corrupting the cache."""
        from ..models.kv_cache import PagedModelState
        sid = StateManager.key(m, request_id)
        st = self.states.get(sid)
        if isinstance(st, PagedModelState):
            sel = (np.ones(st.batch, bool) if rows is None
                   else np.asarray(rows, bool))
            if not sel.any():
                return
            wp = np.asarray(st.write_ptr)[sel]
            nb = np.asarray(st.num_blocks)[sel]
            high = wp + needed
            new_blocks = np.maximum(-(-high // st.block_size) - nb, 0)
            if (high.max() <= st.capacity
                    and int(new_blocks.sum()) <= int(st.free_top)):
                return
            # no defragment to run — paged rows cannot leak holes into each
            # other; a genuine overflow means the session was undersized.
            # ``state_rows`` keeps the rebuild scoped to the rows this
            # model actually holds (lazy chain membership).
            self.states.release(sid)
            self._prefill_model(m, request_id, seq, seq_len, max_len,
                                rows=state_rows)
            self.profiler.count(f"reprefill.{m}")
            return
        if int(st.write_ptr) + needed <= st.capacity:
            return
        self.states.maybe_defragment(sid, force=True)
        self.profiler.count(f"defrag.{m}")
        st = self.states.get(sid)
        if int(st.write_ptr) + needed <= st.capacity:
            return
        self.states.release(sid)
        self._prefill_model(m, request_id, seq, seq_len, max_len,
                            rows=state_rows)
        self.profiler.count(f"reprefill.{m}")

    def _insert_rows(self, m: str, session_id: str, rows: np.ndarray,
                     seq: np.ndarray, seq_len: np.ndarray, max_len: int,
                     state_rows: Optional[np.ndarray] = None
                     ) -> Optional[np.ndarray]:
        """Catch-up prefill of one or more freed rows into a live session
        state: ONE masked forward feeds every row in ``rows`` its
        ``seq[b, :seq_len[b]-1]`` (occupied rows ride along as no-ops) —
        a group of slots joining the same model in one cycle costs one
        insert, not one per row.

        Precondition: each row is already free (retire wiped it, or it
        has been masked-empty since the state was created).

        Returns the (B, V) next-token distributions (rows outside
        ``rows`` are garbage), or None when there was nothing to feed
        (1-token prompts, or the capacity guard rebuilt the state — which
        prefills the new rows too)."""
        B = seq.shape[0]
        sid = StateManager.key(m, session_id)
        rows = np.asarray(rows, bool)
        n = np.where(rows, seq_len - 1, 0)  # cache invariant: seq[:len-1]
        if int(n.max()) <= 0:
            return None
        w_max = 1                      # reserve for the BUCKETED width: the
        while w_max < int(n.max()):    # append is w wide, and an under-
            w_max *= 2                 # reservation would let the slice
        srows = (rows if state_rows is None      # clamp onto live rows
                 else (np.asarray(state_rows, bool) | rows))
        self._ensure_capacity(m, session_id, w_max + 2, seq, seq_len,
                              max_len, rows=rows, state_rows=srows)
        done = self.states.lengths(sid)     # re-prefill may have run
        need = np.where(rows, n - done, 0)
        if int(need.max()) <= 0:
            return None
        w = 1
        while w < int(need.max()):     # pow-2 width buckets bound jit
            w *= 2                     # shapes (w <= w_max)
        tokens = np.zeros((B, w), np.int32)
        valid = np.zeros((B, w), bool)
        for b in np.where(need > 0)[0]:
            tokens[b, :need[b]] = seq[b, done[b]:n[b]]
            valid[b, :need[b]] = True
        probs = self.executor.insert(InsertRequest(
            model=m, request_id=session_id, tokens=tokens, valid=valid))
        self.profiler.count(f"admit.{m}", float(rows.sum()))
        return probs

    def _insert_row(self, m: str, session_id: str, row: int,
                    seq: np.ndarray, seq_len: np.ndarray,
                    max_len: int,
                    state_rows: Optional[np.ndarray] = None
                    ) -> Optional[np.ndarray]:
        """Single-row ``_insert_rows`` (admission): returns the admitted
        row's (1, V) distribution for the similarity probe, or None."""
        rows = np.zeros(seq.shape[0], bool)
        rows[row] = True
        probs = self._insert_rows(m, session_id, rows, seq, seq_len,
                                  max_len, state_rows=state_rows)
        return None if probs is None else probs[row:row + 1]

    def _sync_chain(self, chain: Tuple[str, ...], request_id: str,
                    needed: int, seq: np.ndarray, seq_len: np.ndarray,
                    active: np.ndarray, max_len: int,
                    members: Optional[Dict[str, np.ndarray]] = None
                    ) -> Dict:
        """Catch every chain member up to the committed stream before a
        cycle: capacity guard, gap prefix per model, and a full catch-up
        re-prefill for models that fell beyond the gap bound.  ``members``
        (model -> (B,) bool, lazy membership) scopes any rebuild to the
        rows the model actually holds.  Returns
        {model: (prefix_tokens, prefix_valid)}."""
        prefixes = {}
        for m in chain:
            srows = members.get(m) if members is not None else None
            self._ensure_capacity(m, request_id, needed, seq, seq_len,
                                  max_len, rows=active, state_rows=srows)
            pfx, pval, _gap = self._gap_prefix(m, request_id, seq, seq_len,
                                               active)
            if pfx is None:   # fell too far behind -> catch-up prefill
                self.states.release(StateManager.key(m, request_id))
                self._prefill_model(m, request_id, seq, seq_len, max_len,
                                    rows=srows)
                pfx, pval, _gap = self._gap_prefix(m, request_id, seq,
                                                   seq_len, active)
            prefixes[m] = (pfx, pval)
        return prefixes

    def _apply_termination(self, seq: np.ndarray, seq_len: np.ndarray,
                           prompt_lens: np.ndarray, budget: np.ndarray,
                           active: np.ndarray,
                           scan_from: Optional[np.ndarray] = None) -> None:
        """Per-row termination: budget exhaustion (over-committed tokens in
        the final cycle are truncated — the prefix still equals target-only
        output, so equivalence is preserved) and EOS.

        ``scan_from`` (B,) bounds the EOS scan to tokens committed THIS
        cycle (everything before it was already scanned when it was
        committed) — without it a long generation re-scans its whole output
        every cycle, O(n²) per request."""
        B = seq.shape[0]
        for b in range(B):
            if not active[b]:
                continue
            if seq_len[b] - prompt_lens[b] >= budget[b]:
                seq_len[b] = prompt_lens[b] + budget[b]
                active[b] = False
            if self.eos >= 0:
                start = prompt_lens[b] if scan_from is None else \
                    max(int(scan_from[b]), int(prompt_lens[b]))
                row = seq[b, start:seq_len[b]]
                hits = np.where(row == self.eos)[0]
                if hits.size:
                    seq_len[b] = start + hits[0] + 1
                    active[b] = False

    @staticmethod
    def _commit_rows(seq: np.ndarray, seq_len: np.ndarray,
                     active: np.ndarray, cand: np.ndarray,
                     k: np.ndarray, next_token: np.ndarray) -> None:
        """Vectorized commit (hot decode path): for each active row b,
        ``seq[b, len:len+k[b]] = cand[b, :k[b]]``, then the
        correction/bonus token, then ``seq_len += k+1``.  Fancy-indexed
        scatter replaces the per-row Python loop; outputs are bit-equal
        (the equivalence suite pins this end to end)."""
        rows = np.where(active)[0]
        if rows.size == 0:
            return
        kr = np.asarray(k, np.int64)[rows]
        base = np.asarray(seq_len[rows], np.int64)
        if cand.shape[1]:
            keep = np.arange(cand.shape[1])[None, :] < kr[:, None]
            rr, cc = np.nonzero(keep)
            seq[rows[rr], base[rr] + cc] = cand[rows[rr], cc]
        seq[rows, base + kr] = np.asarray(next_token)[rows]
        seq_len[rows] += kr + 1

    def _observe_slots(self, slot_keys: Optional[Sequence[str]],
                       producer: str, verifier: str, dtv: np.ndarray,
                       active: np.ndarray) -> None:
        """Per-slot acceptance feedback: each active row's verify DTV
        updates that slot's similarity view (the per-slot scheduler's
        evidence), alongside the pool-global EMA."""
        if slot_keys is None or not self.adaptive:
            return
        for b in np.where(active)[0]:
            self.scheduler.observe_slot(slot_keys[b], producer, verifier,
                                        float(dtv[b]))

    # ------------------------------------------------------------------
    def start_session(self, num_slots: int, max_len: int,
                      session_id: str = "sess0") -> "RouterSession":
        """Open a slot-level continuous-batching session (the serving
        engine's entry point; ``generate`` wraps the same machinery)."""
        return RouterSession(self, num_slots, max_len, session_id)

    def generate(self, prompt: np.ndarray, prompt_lens: np.ndarray,
                 max_new_tokens, request_id: str = "req0",
                 capacity_margin: int = 4) -> GenerationResult:
        """Batch generate-to-completion: a bulk wrapper over the slot
        session — every row is admitted up front (one batched prefill,
        identical cost profile to the pre-session code path), then cycles
        run until all rows terminate."""
        B, Tp = prompt.shape
        budget = (np.full(B, max_new_tokens, np.int64)
                  if np.isscalar(max_new_tokens)
                  else np.asarray(max_new_tokens, np.int64))
        max_new = int(budget.max())
        # physical capacity: prompt + worst-case appended blocks (max_block
        # covers the widest linear window or tree node count per cycle)
        max_len = Tp + (max_new + 2) * 2 + self.gcap + \
            (self.max_block + self.scheduler.max_chain_len) * capacity_margin

        sess = self.start_session(B, max_len, session_id=request_id)
        sess.seq[:, :Tp] = prompt
        sess.seq_len[:] = prompt_lens.astype(np.int64)
        sess.prompt_len[:] = sess.seq_len
        sess.budget[:] = budget
        sess.occupied[:] = True
        sess.active[:] = True
        t0 = _time.perf_counter()
        sess.boot()
        prefill_wall = _time.perf_counter() - t0

        acc_lens, cycle_wall, commits_hist = [], [], []
        while sess.active.any() and sess.committed < max_new * B:
            rep = sess.run_cycle()
            cycle_wall.append(rep.wall_s)
            commits_hist.append(rep.commits.copy())
            acc_lens.append(rep.acc_mean)
            if sess.steps > max_new * 4 + 16:   # safety net
                break

        seq, seq_len, prompt_len = sess.seq, sess.seq_len, sess.prompt_len
        seqs = [seq[b, :seq_len[b]].copy() for b in range(B)]
        gens = [seq[b, prompt_len[b]:seq_len[b]].copy() for b in range(B)]
        hist = list(sess.chain_history)
        steps = sess.steps
        sess.close()
        return GenerationResult(seqs, gens, steps,
                                int(sum(len(g) for g in gens)),
                                hist, acc_lens,
                                prefill_wall_s=prefill_wall,
                                cycle_wall_s=cycle_wall,
                                commits_per_cycle=commits_hist)

    # ------------------------------------------------------------------
    def _one_cycle(self, chain: Tuple[str, ...], W: int, request_id: str,
                   seq: np.ndarray, seq_len: np.ndarray,
                   active: np.ndarray,
                   tree: Optional[TokenTree] = None,
                   members: Optional[Dict[str, np.ndarray]] = None,
                   slot_keys: Optional[Sequence[str]] = None) -> np.ndarray:
        """Execute one speculative cycle; mutates seq/seq_len in place.
        Returns per-row committed token count.  A non-None ``tree`` routes
        the cycle through tree-structured speculation (draft a token tree,
        prune per level, one merged target verify).  ``members`` carries
        the session's lazy chain membership (rebuild scoping);
        ``slot_keys`` routes per-row verify DTV into the per-slot
        scheduler views."""
        if tree is not None and len(chain) > 1:
            return self._one_tree_cycle(chain, tree, request_id, seq,
                                        seq_len, active, members=members,
                                        slot_keys=slot_keys)
        B = seq.shape[0]
        max_len = self.states.get(
            StateManager.key(self.target, request_id)).capacity

        # --- ensure chain members are synced (or re-prefill laggards) ----
        prefixes = self._sync_chain(chain, request_id,
                                    self.gcap + 2 + W + len(chain),
                                    seq, seq_len, active, max_len,
                                    members=members)

        # --- target-only chain: plain autoregressive step -----------------
        if len(chain) == 1:
            pfx, pval = prefixes[self.target]
            toks, _probs = self.executor.draft(DraftRequest(
                model=self.target, request_id=request_id,
                prefix_tokens=pfx, prefix_valid=pval, window=1,
                active=active, greedy=self.greedy,
                temperature=self.temperature, rng=self._next_rng()))
            nxt = toks[:, 0]
            n_committed = np.where(active, 1, 0)
            self._commit_rows(seq, seq_len, active,
                              np.zeros((B, 0), np.int32),
                              np.zeros(B, np.int64), nxt)
            return n_committed

        # --- draft --------------------------------------------------------
        m1 = chain[0]
        pfx, pval = prefixes[m1]
        cand, cprobs = self.executor.draft(DraftRequest(
            model=m1, request_id=request_id, prefix_tokens=pfx,
            prefix_valid=pval, window=W, active=active, greedy=self.greedy,
            temperature=self.temperature, rng=self._next_rng()))
        valid_len = np.full((B,), W, np.int32)

        # --- staged verification (levels 2..N) -----------------------------
        ks: List[np.ndarray] = []
        producer = m1
        res = None
        for j, m in enumerate(chain[1:], start=2):
            pfx, pval = prefixes[m]
            res = self.executor.verify(VerifyRequest(
                model=m, request_id=request_id, prefix_tokens=pfx,
                prefix_valid=pval, candidates=cand,
                candidate_probs=cprobs, valid_len=valid_len, active=active,
                greedy=self.greedy, temperature=self.temperature,
                rng=self._next_rng()))
            ks.append(np.asarray(res.num_accepted))
            # similarity feedback (Eq. 5/6) between adjacent chain levels:
            # pool-global EMA + per-slot views (slot-level routing)
            if active.any():
                self.sims.update(producer, m,
                                 float(np.mean(res.dtv[active])))
                self._observe_slots(slot_keys, producer, m,
                                    np.asarray(res.dtv), active)
            self.profiler.count(f"accept.{producer}->{m}",
                                float(np.sum(res.num_accepted[active])))
            if m != chain[-1]:
                cand_j, cprobs_j, vlen = ver.splice_candidates(
                    jax.numpy.asarray(cand),
                    jax.numpy.asarray(cprobs) if cprobs is not None else None,
                    jax.tree.map(jax.numpy.asarray, res))
                cand = np.asarray(cand_j)
                cprobs = np.asarray(cprobs_j) if cprobs_j is not None else None
                valid_len = np.asarray(vlen)
            producer = m

        k_N = np.asarray(res.num_accepted)          # target acceptance
        next_token = np.asarray(res.next_token)

        # --- consensus rollback (paper §4.3 RollbackProcessor) -------------
        # level j in [1..N-1] holds a candidate of length W + (j-1) and
        # rolls back to min(k_j, ..., k_N) — the shared pure function also
        # runs inside the fused cycle program, so both paths settle states
        # identically.
        ks_arr = np.stack(ks, axis=0)               # (N-1, B)
        rbs = np.asarray(ver.consensus_rollbacks(
            jnp.asarray(ks_arr), W, jnp.asarray(active)))
        for j, m in enumerate(chain[:-1], start=1):
            self.executor.rollback(RollbackRequest(
                model=m, request_id=request_id,
                r=rbs[j - 1].astype(np.int32)))
        # target rolls back its own rejects
        self.executor.rollback(RollbackRequest(
            model=chain[-1], request_id=request_id,
            r=np.asarray(res.rollback, np.int32)))

        # --- commit ---------------------------------------------------------
        n_committed = np.where(active, k_N + 1, 0)
        self._commit_rows(seq, seq_len, active, cand, k_N, next_token)
        self.profiler.count("cycles")
        self.profiler.count("committed", float(n_committed.sum()))
        return n_committed

    # ------------------------------------------------------------------
    def _one_tree_cycle(self, chain: Tuple[str, ...], tree: TokenTree,
                        request_id: str, seq: np.ndarray,
                        seq_len: np.ndarray,
                        active: np.ndarray,
                        members: Optional[Dict[str, np.ndarray]] = None,
                        slot_keys: Optional[Sequence[str]] = None
                        ) -> np.ndarray:
        """One tree-structured speculative cycle (SpecInfer-style):

          1. the draft model emits a token tree (static shape, level by
             level, ancestor-masked attention);
          2. every intermediate chain model verifies the WHOLE tree in one
             pass and prunes the sub-trees it rejects (multi-level
             collaboration: the target only considers surviving nodes);
          3. the target's single merged pass accepts the deepest surviving
             root-to-leaf prefix and yields the correction/bonus token;
          4. every model settles its tree block by consensus: keep the
             winning-path nodes all deeper levels also accepted, mask the
             dead branches (ResolveTree = the tree RollbackProcessor).

        Greedy mode commits exactly the target-only greedy stream (at most
        one child per node can match the target argmax).  Pruning can only
        drop candidates, never add them, so bit-equality survives any
        intermediate pruning decisions."""
        B = seq.shape[0]
        N, D = tree.num_nodes, tree.depth_levels
        max_len = self.states.get(
            StateManager.key(self.target, request_id)).capacity

        for m in chain:
            assert self.pool.cfg(m).supports_tree, \
                f"{m} cannot decode token trees"
        prefixes = self._sync_chain(chain, request_id, self.gcap + 2 + N,
                                    seq, seq_len, active, max_len,
                                    members=members)

        # --- draft the tree ------------------------------------------------
        m1 = chain[0]
        pfx, pval = prefixes[m1]
        cand, cprobs = self.executor.draft_tree(DraftTreeRequest(
            model=m1, request_id=request_id, prefix_tokens=pfx,
            prefix_valid=pval, tree=tree, active=active, greedy=self.greedy,
            temperature=self.temperature, rng=self._next_rng()))

        # --- per-level prune, then the target's merged verify --------------
        node_valid = np.broadcast_to(active[:, None], (B, N)).copy()
        accepts: List[np.ndarray] = []
        producer = m1
        res = None
        for m in chain[1:]:
            final = m == chain[-1]
            pfx, pval = prefixes[m]
            res = self.executor.verify_tree(VerifyTreeRequest(
                model=m, request_id=request_id, prefix_tokens=pfx,
                prefix_valid=pval, tree=tree, candidates=cand,
                candidate_probs=cprobs, node_valid=node_valid,
                active=active, greedy=self.greedy,
                temperature=self.temperature, final=final,
                rng=self._next_rng()))
            accepts.append(np.asarray(res.accept))
            if active.any():
                # every tree level verifies the DRAFT's candidate_probs
                # (no per-level re-splicing), so res.dtv measures the
                # draft-vs-this-verifier divergence — attribute it to that
                # pair, not to the adjacent chain edge
                self.sims.update(m1, m, float(np.mean(res.dtv[active])))
                self._observe_slots(slot_keys, m1, m,
                                    np.asarray(res.dtv), active)
            self.profiler.count(f"accept.{producer}->{m}",
                                float(np.sum(res.num_accepted[active])))
            if not final:   # prune: mask the sub-trees this level rejected
                node_valid = node_valid & np.asarray(res.accept)
            producer = m

        k_N = np.asarray(res.num_accepted)
        path = np.asarray(res.path_nodes)
        next_token = np.asarray(res.next_token)

        # --- consensus resolve (tree analogue of RollbackProcessor) --------
        # level j keeps the winning-path prefix that IT and every deeper
        # level accepted: min over the per-level accepted depths along the
        # target's winning path (the draft keeps the min over all levels);
        # the shared pure function also runs inside the fused tree program.
        keeps = np.asarray(ver.tree_consensus_keep(
            [jnp.asarray(a) for a in accepts], jnp.asarray(path),
            jnp.asarray(k_N), jnp.asarray(active)))
        for j, m in enumerate(chain):
            self.executor.resolve_tree(ResolveTreeRequest(
                model=m, request_id=request_id, tree=tree,
                path_nodes=path, keep_len=keeps[j], active=active))

        # --- commit the winning path + correction/bonus --------------------
        path_tokens = np.take_along_axis(cand, path, axis=1)   # (B, D)
        n_committed = np.where(active, k_N + 1, 0)
        self._commit_rows(seq, seq_len, active, path_tokens, k_N,
                          next_token)
        self.profiler.count("cycles")
        self.profiler.count("committed", float(n_committed.sum()))
        return n_committed


class RouterSession:
    """Slot-level continuous-batching handle (§4 asynchronous batching).

    A session owns a fixed pool of ``num_slots`` slots backed by one
    batch-sized ModelState per CHAIN-MEMBER model (state key
    ``model/session_id``).  Request lifecycle per slot:

        QUEUED --admit()--> PREFILL --> DECODING --retire()--> DONE
                 (chain assigned;       (run_cycle() groups
                  catch-up prefill       active slots by chain
                  of the CHAIN's         and advances each
                  models only; live      group in one masked
                  rows are masked        sub-cycle)
                  no-ops)

    Chain membership is per-slot and LAZY: ``admit`` assigns the slot a
    chain (``get_optimal_chain(slot)`` seeded by the global prior, or an
    explicit ``chain=`` override) and materializes its row only in that
    chain's models — O(chain) prefill work, not O(pool).  Rescheduling may
    reassign the chain later: leaving models free the slot's row
    immediately, joining models catch up through ``_insert_row`` (priced
    by the scheduler's switch penalty).  ``retire`` frees exactly the
    member rows.  With ``router.slot_routing=False`` the legacy behaviour
    is preserved: one global chain per cycle and every pool model
    materialized at admission (the O(pool) A/B baseline).
    """

    def __init__(self, router: ChainRouter, num_slots: int, max_len: int,
                 session_id: str = "sess0"):
        self.router = router
        self.num_slots = int(num_slots)
        self.max_len = int(max_len)
        self.session_id = session_id
        B = self.num_slots
        self.seq = np.zeros((B, self.max_len + 8), np.int32)
        self.seq_len = np.zeros(B, np.int64)
        self.prompt_len = np.zeros(B, np.int64)
        self.budget = np.zeros(B, np.int64)
        self.occupied = np.zeros(B, bool)   # slot holds a live request
        self.active = np.zeros(B, bool)     # still generating
        self.steps = 0
        self.committed = 0
        # diagnostics ring: one (chain, window) entry per sub-cycle group
        # — bounded, or an indefinite serving session leaks it at
        # O(groups · cycles) (same accumulator class as the profiler
        # trace, which is capped for the same reason)
        self.chain_history: collections.deque = \
            collections.deque(maxlen=4096)
        # lazy chain membership: model -> (B,) bool, True where the
        # slot's row is materialized in that model's session state
        self._members: Dict[str, np.ndarray] = {}
        self._slot_choice: List[Optional[ChainChoice]] = [None] * B
        self._forced: np.ndarray = np.zeros(B, bool)  # admit(chain=...)
        self._global_choice: Optional[ChainChoice] = None  # legacy engine
        # device-resident session buffers (fused cycles): the numpy arrays
        # above are the HOST MIRROR, rebuilt exactly from each fused
        # cycle's summary slab; ``_dev`` holds the authoritative device
        # copies between fused cycles and is re-uploaded whenever a host
        # path (admission, retirement, an unfused profiling cycle) has
        # mutated the mirror (``_dev_stale``).
        self._dev: Optional[Dict[str, jax.Array]] = None
        self._dev_stale = True
        # summary-fed host views of per-model cache cursors, so the fused
        # path's gap/capacity preflight costs no device sync; cleared by
        # any host-path state op (prefill/insert/free/unfused cycle)
        self._len_cache: Dict[str, np.ndarray] = {}
        self._wp_cache: Dict[str, tuple] = {}

    # ---- scheduling helpers -------------------------------------------
    def _skey(self, slot: int) -> str:
        """Per-slot scheduler key, namespaced so concurrent sessions on
        one router cannot collide on physical slot indices."""
        return f"{self.session_id}:{slot}"

    def _fixed_choice(self) -> ChainChoice:
        r = self.router
        w = (r.fixed_tree.depth_levels if r.fixed_tree is not None
             else (r.fixed_window or 4))
        return ChainChoice(r.fixed_chain, w, 0.0, tree=r.fixed_tree)

    def _choose(self, slot: int) -> ChainChoice:
        r = self.router
        if r.fixed_chain is not None:
            return self._fixed_choice()
        if not r.slot_routing:
            return r.scheduler.get_optimal_chain()
        return r.scheduler.get_optimal_chain(slot=self._skey(slot))

    def _admit_models(self, chain: Tuple[str, ...]) -> Tuple[str, ...]:
        """Which models an admission materializes: the slot's chain
        (lazy membership) or the whole pool (legacy baseline)."""
        if self.router.slot_routing:
            return chain
        return tuple(self.router.pool.names())

    # ---- membership surgery -------------------------------------------
    def _invalidate_state_caches(self) -> None:
        """A host-path state op ran (prefill/insert/free/unfused cycle):
        the summary-fed cursor views are stale — drop them; the next fused
        preflight re-reads from the live states (that path just synced
        anyway, so the extra read is free)."""
        self._len_cache.clear()
        self._wp_cache.clear()

    def _materialize_row(self, m: str, slot: int) -> Optional[np.ndarray]:
        """Ensure model ``m`` holds slot ``slot``'s committed stream:
        create the session state (row-scoped prefill) if this is the
        model's first member, else catch the row up via ``_insert_row``.
        Returns the row's (1, V) next-token distribution when a forward
        ran (the admission similarity probe), else None."""
        r = self.router
        B = self.num_slots
        mem = self._members.setdefault(m, np.zeros(B, bool))
        if mem[slot]:
            return None
        self._invalidate_state_caches()
        sid = StateManager.key(m, self.session_id)
        if not r.states.exists(sid):
            rows = np.zeros(B, bool)
            rows[slot] = True
            probs = r._prefill_model(m, self.session_id, self.seq,
                                     self.seq_len, self.max_len, rows=rows)
            mem[slot] = True
            r.profiler.count(f"admit.{m}")
            return probs[slot:slot + 1]
        p = r._insert_row(m, self.session_id, slot, self.seq,
                          self.seq_len, self.max_len, state_rows=mem)
        mem[slot] = True
        return p

    def _release_member(self, m: str, slot: int) -> None:
        """Free one slot's row in one model (chain reassignment dropped
        the model, or the slot retired).  When the model's last member
        leaves, the whole session state is released — a pool model no
        slot routes through holds nothing at all."""
        mem = self._members.get(m)
        if mem is None or not mem[slot]:
            return
        self._invalidate_state_caches()
        rows = np.zeros(self.num_slots, bool)
        rows[slot] = True
        self.router.executor.retire(m, self.session_id, rows)
        mem[slot] = False
        if not mem.any():
            self.router.states.release(
                StateManager.key(m, self.session_id))
            self._members.pop(m, None)

    def _ensure_members(self, chain: Tuple[str, ...],
                        rows: np.ndarray) -> None:
        """Lazy join: materialize any (model, row) of the group that is
        not yet a member (a model that entered the slot's chain after
        admission catches up through the insert path).  All of a model's
        joining rows share ONE batched prefill/insert forward."""
        r = self.router
        for m in chain:
            mem = self._members.setdefault(
                m, np.zeros(self.num_slots, bool))
            missing = rows & ~mem
            if not missing.any():
                continue
            self._invalidate_state_caches()
            sid = StateManager.key(m, self.session_id)
            if not r.states.exists(sid):
                r._prefill_model(m, self.session_id, self.seq,
                                 self.seq_len, self.max_len, rows=missing)
                r.profiler.count(f"admit.{m}", float(missing.sum()))
            else:
                self.router._insert_rows(m, self.session_id, missing,
                                         self.seq, self.seq_len,
                                         self.max_len, state_rows=mem)
            mem |= missing

    # ---- lifecycle ----------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s for s in range(self.num_slots) if not self.occupied[s]]

    def admit(self, slot: int, prompt: np.ndarray,
              max_new_tokens: int,
              chain: Optional[Sequence[str]] = None,
              window: Optional[int] = None,
              tree=None,
              ttft_slo_s: Optional[float] = None,
              tpot_slo_s: Optional[float] = None) -> float:
        """Admit a request into a free slot (QUEUED -> PREFILL): assign
        the slot a chain, write its prompt into the slot row, and
        catch-up-prefill the CHAIN members only (the whole pool when
        ``router.slot_routing=False``).  An explicit ``chain``/``window``/
        ``tree`` pins the slot's routing (bypassing the scheduler).
        ``ttft_slo_s``/``tpot_slo_s`` attach the request's SLOs to the
        slot's chain search (the goodput objective's per-slot inputs;
        cleared at retirement).
        Returns the measured admission wall time in seconds.

        Raises ValueError — before any slot state is touched — when the
        prompt plus generation budget cannot fit the slot row."""
        assert not self.occupied[slot], f"slot {slot} is occupied"
        prompt = np.asarray(prompt)
        Lp = int(len(prompt))
        assert Lp >= 1, "empty prompt"
        r = self.router
        # validate capacity BEFORE mutating occupied/active/seq: a
        # mid-admission failure must not leave the session inconsistent
        need = Lp + int(max_new_tokens) + r.max_block + 2
        if need > self.max_len:
            raise ValueError(
                f"request needs {need} slots (prompt {Lp} + budget "
                f"{int(max_new_tokens)} + speculation margin) but the "
                f"session rows hold {self.max_len}; admit rejected")
        if chain is not None:
            chain = tuple(chain)
            assert chain[-1] == r.target, \
                f"explicit chain must end with the target {r.target!r}"
            assert len(set(chain)) == len(chain), \
                "chains cannot repeat a model"
            unknown = [m for m in chain if m not in r.pool.names()]
            if unknown:   # must reject BEFORE mutating slot state — a
                raise ValueError(   # KeyError mid-admission leaks the slot
                    f"chain names models not in the pool: {unknown}")
            choice = ChainChoice(
                chain, (window or (r.fixed_window or 4)), 0.0,
                tree=TokenTree.parse(tree) if tree is not None else None)
        else:
            choice = None
        t0 = _time.perf_counter()
        self._dev_stale = True      # host mirror mutates: re-upload before
        self.seq[slot, :] = 0       # the next fused cycle
        self.seq[slot, :Lp] = prompt
        self.seq_len[slot] = Lp
        self.prompt_len[slot] = Lp
        self.budget[slot] = int(max_new_tokens)
        self.occupied[slot] = True
        self.active[slot] = True
        # SLOs must be attached BEFORE the chain choice: the goodput
        # objective's TPOT-feasibility term reads them
        r.scheduler.set_slot_slo(self._skey(slot), ttft_slo_s, tpot_slo_s)
        if choice is None:
            choice = self._choose(slot)
        self._slot_choice[slot] = choice
        self._forced[slot] = chain is not None
        probe: Dict[str, np.ndarray] = {}
        for m in self._admit_models(choice.chain):
            p = self._materialize_row(m, slot)
            if p is not None:
                probe[m] = p
        if len(probe) >= 2:   # admission doubles as a similarity probe
            dtvs = pairwise_dtv(probe)
            r.sims.update_many(dtvs)
            if r.slot_routing and r.adaptive:
                for (a, b), v in dtvs.items():
                    r.scheduler.observe_slot(self._skey(slot), a, b, v)
        return _time.perf_counter() - t0

    def boot(self) -> None:
        """Bulk admission (``ChainRouter.generate``): assign every
        occupied slot its chain, then materialize each model once with a
        BATCHED row-scoped prefill over the union of rows routed through
        it, seeding global + per-slot similarity from the probe."""
        r = self.router
        B = self.num_slots
        self._dev_stale = True
        self._invalidate_state_caches()
        occ = np.where(self.occupied)[0]
        for s in occ:
            if self._slot_choice[s] is None:
                self._slot_choice[s] = self._choose(int(s))
        want: Dict[str, np.ndarray] = {}
        for s in occ:
            for m in self._admit_models(self._slot_choice[s].chain):
                want.setdefault(m, np.zeros(B, bool))[s] = True
        probes: Dict[str, np.ndarray] = {}
        for m, rows in want.items():
            probes[m] = r._prefill_model(m, self.session_id, self.seq,
                                         self.seq_len, self.max_len,
                                         rows=rows)
            mem = self._members.setdefault(m, np.zeros(B, bool))
            mem |= rows
            r.profiler.count(f"admit.{m}", float(rows.sum()))
        for (a, b), v in pairwise_dtv_rows(probes).items():
            rows = want[a] & want[b]
            if not rows.any():
                continue
            r.sims.update(a, b, float(np.mean(v[rows])))
            if r.slot_routing and r.adaptive:
                for s in np.where(rows)[0]:
                    r.scheduler.observe_slot(self._skey(int(s)), a, b,
                                             float(v[s]))

    def _reschedule(self) -> None:
        """Refresh per-slot choices; on a chain change, free the leaving
        models' rows (joiners materialize lazily at the next sub-cycle)."""
        r = self.router
        if r.fixed_chain is not None:
            for s in np.where(self.active)[0]:
                if self._slot_choice[s] is None:
                    self._slot_choice[s] = self._fixed_choice()
            return
        resched = r.adaptive and self.steps % r.reschedule_every == 0
        if not r.slot_routing:
            # legacy-engine fidelity: ONE shared global chain per cycle
            # for every (non-pinned) slot, refreshed on the reschedule
            # cadence — slots admitted mid-interval must not capture a
            # drifted global choice and split the cycle into groups.
            # Membership stays materialized across switches, exactly like
            # the old engine (laggards catch up through the gap path).
            if self._global_choice is None or resched:
                self._global_choice = r.scheduler.get_optimal_chain()
            for s in np.where(self.active)[0]:
                if not self._forced[s]:
                    self._slot_choice[s] = self._global_choice
            return
        for s in np.where(self.active)[0]:
            cur = self._slot_choice[s]
            if cur is not None and (self._forced[s] or not resched):
                continue
            new = self._choose(int(s))
            if cur is not None and new.chain != cur.chain:
                for m in set(cur.chain) - set(new.chain):
                    self._release_member(m, int(s))
            self._slot_choice[s] = new

    # ---- device-resident fused cycles ---------------------------------
    def _sync_device(self) -> None:
        """Upload the host mirror into the device session buffers if a
        host path mutated it since the last fused cycle."""
        if self._dev is not None and not self._dev_stale:
            return
        # under a real mesh the session buffers are explicitly replicated
        # (every member's slice reads them); trivial placement keeps the
        # plain single-device upload
        rep = self.router.placement.replicated_sharding()

        def up(x):
            a = jnp.asarray(x)
            return a if rep is None else jax.device_put(a, rep)

        self._dev = {
            "seq": up(self.seq),
            "seq_len": up(self.seq_len.astype(np.int32)),
            "prompt_len": up(self.prompt_len.astype(np.int32)),
            "budget": up(self.budget.astype(np.int32)),
            "active": up(self.active),
        }
        self._dev_stale = False

    def _cached_lengths(self, m: str) -> np.ndarray:
        """Per-row cache lengths for model ``m`` — the summary-fed view
        when fresh, else one read from the live state."""
        v = self._len_cache.get(m)
        if v is None:
            v = self.router.states.lengths(
                StateManager.key(m, self.session_id))
            self._len_cache[m] = v
        return v

    def _chain_timed(self, chain: Tuple[str, ...], tree) -> bool:
        """True when every chain member has per-op timing evidence (the
        scheduler's Eq. 7 inputs): draft decode (decode_level for the
        tree's shape) and a verify EMA per verifier level."""
        emas = self.router.profiler.emas
        pq = self.router.placement.qualify
        draft_key = (("decode_level", pq(chain[0]), tree.branching)
                     if tree is not None else ("decode1", pq(chain[0])))
        e = emas.get(draft_key)
        if e is None or e.count == 0:
            return False
        for m in chain[1:]:
            qm = pq(m)
            if not any(k[0] == "verify" and k[1] == qm and e.count
                       for k, e in emas.items() if len(k) == 3):
                return False
        return True

    def _fused_capacity_ok(self, m: str, needed: int,
                           rows: np.ndarray) -> bool:
        """Non-mutating mirror of ``_ensure_capacity``: True when model
        ``m`` can absorb ``needed`` more entries for every row in ``rows``
        without a defrag/rebuild escape (which only the per-op path runs)."""
        from ..models.kv_cache import PagedModelState
        r = self.router
        st = r.states.get(StateManager.key(m, self.session_id))
        info = self._wp_cache.get(m)
        if isinstance(st, PagedModelState):
            if info is None:
                info = (np.asarray(st.write_ptr), int(st.free_top),
                        np.asarray(st.num_blocks))
                self._wp_cache[m] = info
            wp, free_top, nb = info
            sel = np.asarray(rows, bool)
            if not sel.any():
                return True
            high = wp[sel] + needed
            new_blocks = np.maximum(
                -(-high // st.block_size) - nb[sel], 0)
            return bool(high.max() <= st.capacity
                        and int(new_blocks.sum()) <= int(free_top))
        if info is None:
            info = (np.asarray(st.write_ptr), None, None)
            self._wp_cache[m] = info
        return bool(int(np.max(info[0])) + needed <= st.capacity)

    def _run_fused_group(self, choice: ChainChoice, gmask: np.ndarray,
                         slot_keys: Optional[Sequence[str]]
                         ) -> Optional[np.ndarray]:
        """Run one sub-cycle group as a single device program.  Returns
        per-row raw commits, or None when the group must fall back to the
        per-op path this cycle (capacity pressure, or a catch-up gap wider
        than the program's static prefix — both are the legacy path's
        escape hatches)."""
        r = self.router
        chain = choice.chain
        tree = choice.tree if (choice.tree is not None
                               and len(chain) > 1) else None
        # a chain member with NO per-op timing evidence yet (a freshly
        # explored model) runs per-op this cycle: fused cycles produce no
        # T_i measurements, so without this the scheduler could keep
        # exploring a slow chain forever between profiling cycles — the
        # first cycle of any new chain doubles as its profiling cycle
        # (benchmarks/routing_ab.py pins the resulting decoy-kill
        # behaviour under the fused default)
        if not self._chain_timed(chain, tree):
            return None
        depth = tree.depth_levels if tree is not None else choice.window
        # prefix-width bound: the worst-case consensus gap is the target's
        # max accepted length (W + N - 2 linear, D tree); +1 for t_last,
        # +1 slack.  target-only chains never lag by more than 1.
        p_max = (depth + len(chain)) if len(chain) > 1 else 2
        gmax = 0
        for m in chain:
            lens = self._cached_lengths(m)
            gap = np.where(gmask, (self.seq_len - 1) - lens, 0)
            if gap.min() < 0 or gap.max() > p_max - 1:
                return None          # needs the re-prefill escape
            gmax = max(gmax, int(gap.max()))
        # pow-2 prefix-width buckets (min 2 = [t_last] + 1 gap slot), like
        # the per-op path's gap buckets: the steady-state cycle (gap 0)
        # runs the narrow program; wide variants compile only when a real
        # catch-up gap appears, instead of every cycle paying p_max-wide
        # draft/verify blocks
        P = 2
        while P - 1 < gmax:
            P *= 2
        P = min(P, p_max)
        block = tree.num_nodes if tree is not None else choice.window
        needed = P + block + len(chain)
        for m in chain:
            if not self._fused_capacity_ok(m, needed, gmask):
                return None          # needs the defrag/rebuild escape
        self._sync_device()
        rngs = tuple(r._next_rng() for _ in chain)
        ok = False
        try:
            bufs, s = r.executor.fused_cycle(FusedCycleRequest(
                chain=chain, request_id=self.session_id,
                window=choice.window, tree=tree, prefix_width=P, eos=r.eos,
                seq=self._dev["seq"], seq_len=self._dev["seq_len"],
                prompt_len=self._dev["prompt_len"],
                budget=self._dev["budget"], active=self._dev["active"],
                gmask=jnp.asarray(gmask), rngs=rngs, greedy=r.greedy,
                temperature=r.temperature))
            ok = True
        finally:
            # on ANY failure (including KeyboardInterrupt) the donated
            # device buffers may have been consumed: drop them so a caller
            # that survives the error re-uploads the (still-exact) host
            # mirror instead of passing deleted arrays into the next
            # program.  try/finally, not a broad except: nothing is
            # swallowed, cleanup runs for every exception type
            if not ok:
                self._dev = None
                self._dev_stale = True
        self._dev.update(bufs)
        # --- mirror the one-transfer summary onto the host ----------------
        cnum = s.n_committed.astype(np.int64)
        rows = np.where(cnum > 0)[0]
        if rows.size:
            keep = (np.arange(s.slab.shape[1])[None, :]
                    < cnum[rows][:, None])
            rr, cc = np.nonzero(keep)
            self.seq[rows[rr], self.seq_len[rows][rr] + cc] = \
                s.slab[rows[rr], cc]
        self.seq_len[:] = np.where(gmask, s.new_seq_len, self.seq_len)
        self.active[:] = np.where(gmask, s.new_active, self.active)
        for i, m in enumerate(chain):
            self._len_cache[m] = s.lengths[i]
            self._wp_cache[m] = (s.write_ptr[i], int(s.free_top[i]),
                                 s.num_blocks[i])
        # --- feedback loops (same signals/keys the per-op cycle emits) ----
        # tree cycles verify the DRAFT's candidate probs at every level,
        # so DTV is attributed to the (draft, verifier) pair; the accept
        # counters bill adjacent chain edges on both paths
        any_run = bool(gmask.any())
        for lvl in range(s.accepts.shape[0]):
            sim_prod = chain[0] if tree is not None else chain[lvl]
            verif = chain[lvl + 1]
            if any_run:
                r.sims.update(sim_prod, verif,
                              float(np.mean(s.dtv[lvl][gmask])))
                r._observe_slots(slot_keys, sim_prod, verif, s.dtv[lvl],
                                 gmask)
            r.profiler.count(f"accept.{chain[lvl]}->{verif}",
                             float(np.sum(s.accepts[lvl][gmask])))
        if len(chain) > 1:
            r.profiler.count("cycles")
            r.profiler.count("committed", float(cnum.sum()))
        return cnum

    def run_cycle(self) -> CycleReport:
        """One speculative cycle over every active slot (DECODING step).
        Active slots are grouped by their assigned (chain, window, tree)
        and each group runs one masked sub-cycle — batched kernels keep
        their static shapes, rows outside the group ride along as no-ops,
        and per-slot greedy output is bit-exact to target-only decoding
        regardless of the grouping.  Per-slot budget/EOS termination is
        applied after the cycle.

        With ``router.fused`` (default) each group is one device program
        and one host transfer; every ``profile_every``-th cycle instead
        runs the per-op path to refresh the scheduler's timings."""
        r = self.router
        B = self.num_slots
        if not self.active.any():
            return CycleReport(np.zeros(B, np.int64), 0.0, (), 0, 0.0)
        self._reschedule()
        # group slots by assigned (chain, window, tree shape)
        groups: Dict[tuple, np.ndarray] = {}
        order: List[tuple] = []
        for s in np.where(self.active)[0]:
            c = self._slot_choice[s]
            key = (c.chain, c.window,
                   c.tree.branching if c.tree is not None else None)
            if key not in groups:
                groups[key] = np.zeros(B, bool)
                order.append(key)
            groups[key][s] = True
        slot_keys = ([self._skey(s) for s in range(B)]
                     if r.slot_routing else None)
        pre_active = self.active.copy()
        gen_before = (self.seq_len - self.prompt_len).copy()
        n_acc = np.zeros(B, np.int64)
        ginfo: List[Tuple[Tuple[str, ...], int, int]] = []
        profiling = (not r.fused) or (r.profile_every > 0
                                      and self.steps % r.profile_every == 0)
        t0 = _time.perf_counter()
        for key in order:
            gmask = groups[key] & self.active
            if not gmask.any():
                continue
            first = int(np.where(gmask)[0][0])
            choice = self._slot_choice[first]
            self._ensure_members(choice.chain, gmask)
            acc = None
            if r.fused and not profiling:
                acc = self._run_fused_group(choice, gmask, slot_keys)
            if acc is None:          # profiling cycle or fused fallback
                acc = r._one_cycle(choice.chain, choice.window,
                                   self.session_id, self.seq,
                                   self.seq_len, gmask, tree=choice.tree,
                                   members=self._members,
                                   slot_keys=slot_keys)
                # the per-op path mutated host state directly: device
                # buffers and summary-fed cursor views are stale (a later
                # fused group this cycle must re-upload)
                self._dev_stale = True
                self._invalidate_state_caches()
            n_acc += np.asarray(acc, np.int64)   # groups are row-disjoint
            self.chain_history.append((choice.chain, choice.window))
            ginfo.append((choice.chain, choice.window, int(gmask.sum())))
        wall = _time.perf_counter() - t0
        # cycle-latency EMA: the load signal's "seconds a queued request
        # waits per cycle boundary" (admission runs between cycles)
        r.profiler.record("cycle_wall", "session", wall)
        acc_mean = float(np.mean(n_acc[pre_active]))
        self.steps += 1
        # EOS scan covers only this cycle's commits (earlier tokens were
        # scanned the cycle they landed) — O(commits), not O(generated)
        scan_from = np.maximum(gen_before + self.prompt_len,
                               self.prompt_len)
        r._apply_termination(self.seq, self.seq_len, self.prompt_len,
                             self.budget, self.active, scan_from=scan_from)
        # acceptance diagnostics report the RAW speculative commit, but the
        # session's committed counter only advances by tokens that SURVIVED
        # termination (budget truncation / EOS cut): tree cycles commit
        # several tokens at once, and counting the truncated overshoot let
        # bulk generate's budget loop exit while rows were still active
        survived = np.where(pre_active,
                            (self.seq_len - self.prompt_len) - gen_before,
                            0).astype(np.int64)
        self.committed += int(survived.sum())
        lead = ginfo[0] if ginfo else ((), 0, 0)
        return CycleReport(n_acc, wall, lead[0], lead[1], acc_mean,
                           groups=ginfo)

    def generated(self, slot: int) -> np.ndarray:
        """The slot's committed output tokens so far (prompt excluded)."""
        return self.seq[slot,
                        self.prompt_len[slot]:self.seq_len[slot]].copy()

    def retire(self, slot: int) -> np.ndarray:
        """Free a finished slot (DECODING -> DONE) and return its output.
        Only the slot's CHAIN-MEMBER rows are released (recurrent carries
        wiped); pool models outside its chain never held anything.  Live
        slots are untouched."""
        out = self.generated(slot)
        for m in list(self._members):
            self._release_member(m, slot)
        self._dev_stale = True
        self.occupied[slot] = False
        self.active[slot] = False
        self.seq_len[slot] = 0
        self.prompt_len[slot] = 0
        self._slot_choice[slot] = None
        self._forced[slot] = False
        self.router.scheduler.release_slot(self._skey(slot))
        return out

    def close(self) -> None:
        """Release every model state owned by this session, plus the
        scheduler's per-slot views."""
        self.router.states.release_request(self.session_id)
        for s in range(self.num_slots):
            self.router.scheduler.release_slot(self._skey(s))
        self._members.clear()
        self._slot_choice = [None] * self.num_slots
        self._forced[:] = False
        self._dev = None
        self._dev_stale = True
        self._invalidate_state_caches()
