"""ChainRouter (paper §4.1): central coordination of the multi-level
speculative generation loop (Listing 1).

Per cycle:
  1. get the optimal chain + window from the ModelChainScheduler;
  2. DraftRequest to M_1 (with per-model gap catch-up prefix);
  3. VerifyRequest to M_2 … M_t, splicing corrected candidates between
     levels (§4.3);
  4. consensus rollback: model at level j rolls back to
     min(k_j, …, k_N) — the prefix of ITS cached candidate that survived
     every deeper verifier (the paper's 'rollback length … based on
     consensus');
  5. commit target-accepted tokens + bonus/correction, update termination.

State sync invariant: a model's cache holds exactly ``seq[:seq_len-1]`` for
each row once its gap is caught up; gaps (from consensus < k_N) are
re-fed as the masked prefix of its next block (DESIGN §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import verification as ver
from .executor import (DraftRequest, Executor, PrefillRequest,
                       RollbackRequest, VerifyRequest)
from .model_pool import ModelPool
from .profiler import PerformanceProfiler
from .scheduler import ChainChoice, ModelChainScheduler
from .similarity import SimilarityStore, pairwise_dtv
from .state_manager import StateManager


@dataclasses.dataclass
class GenerationResult:
    sequences: List[np.ndarray]      # per row: prompt + generated (trimmed)
    generated: List[np.ndarray]      # per row: generated only
    steps: int                       # speculative cycles executed
    committed_tokens: int
    chain_history: List[Tuple[Tuple[str, ...], int]]
    acceptance_lengths: List[float]  # mean accepted per cycle (diagnostics)
    prefill_wall_s: float = 0.0
    cycle_wall_s: List[float] = dataclasses.field(default_factory=list)
    commits_per_cycle: List[np.ndarray] = dataclasses.field(
        default_factory=list)     # (B,) per cycle


class ChainRouter:
    def __init__(self, pool: ModelPool, target: str,
                 eos_token: int = -1,
                 greedy: bool = True,
                 temperature: float = 1.0,
                 adaptive: bool = True,
                 fixed_chain: Optional[Sequence[str]] = None,
                 fixed_window: Optional[int] = None,
                 windows: Sequence[int] = (2, 3, 4, 6),
                 max_chain_len: int = 3,
                 reschedule_every: int = 1,
                 seed: int = 0,
                 profiler: Optional[PerformanceProfiler] = None):
        self.pool = pool
        self.target = target
        self.eos = eos_token
        self.greedy = greedy
        self.temperature = temperature
        self.adaptive = adaptive
        self.fixed_chain = tuple(fixed_chain) if fixed_chain else None
        if self.fixed_chain is not None:
            assert len(set(self.fixed_chain)) == len(self.fixed_chain), \
                "chains cannot repeat a model (states are keyed by name)"
            assert self.fixed_chain[-1] == target
        self.fixed_window = fixed_window
        self.reschedule_every = reschedule_every
        self.profiler = profiler or PerformanceProfiler()
        self.states = StateManager()
        self.executor = Executor(pool, self.states, self.profiler)
        self.sims = SimilarityStore()
        self.scheduler = ModelChainScheduler(
            pool.names(), target, self.profiler, self.sims,
            pool.capability(), max_chain_len=max_chain_len, windows=windows)
        self.rng = jax.random.PRNGKey(seed)
        # static gap-prefix width: one jit shape per (model, Tc)
        self.gcap = max(windows) + max_chain_len + 2

    # ------------------------------------------------------------------
    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    def _prefill_model(self, m: str, request_id: str, seq: np.ndarray,
                       seq_len: np.ndarray, max_len: int):
        """(Re-)create model m's state holding seq[:seq_len-1] per row."""
        S = int(seq_len.max())
        seq = seq[:, :S]
        B = seq.shape[0]
        idx = np.arange(S)[None, :]
        valid = idx < (seq_len - 1)[:, None]
        cfg = self.pool.cfg(m)
        extras = self.pool.model(m).extras_for(B)
        probs, _sid = self.executor.prefill(PrefillRequest(
            model=m, request_id=request_id, tokens=seq.astype(np.int32),
            valid=valid, max_len=max_len,
            with_snaps=cfg.arch_type in ("ssm", "hybrid"), extras=extras))
        return probs

    def _gap_prefix(self, m: str, request_id: str, seq, seq_len, active):
        """Build [pads…, gap tokens…, t_last] (B, w) + valid mask, with w
        the smallest width bucket covering the largest row gap (buckets keep
        the jit-shape count bounded while avoiding gcap-wide pad waste).

        Returns (None, None, gap) if a gap exceeds gcap (caller re-prefills).
        """
        B = seq.shape[0]
        sid = StateManager.key(m, request_id)
        cache_len = self.states.lengths(sid)          # (B,)
        gap = (seq_len - 1) - cache_len               # tokens missing
        gap = np.where(active, gap, 0)
        if gap.min() < 0 or gap.max() > self.gcap:
            return None, None, gap
        w = 1
        for bucket in (1, 2, 4, 8, self.gcap + 1):
            if bucket >= int(gap.max()) + 1:
                w = bucket
                break
        prefix = np.zeros((B, w), np.int32)
        pvalid = np.zeros((B, w), bool)
        for b in range(B):
            g = int(gap[b])
            if g > 0:   # right-aligned: real tokens contiguous before t_last
                prefix[b, w - 1 - g:w - 1] = \
                    seq[b, cache_len[b]:cache_len[b] + g]
                pvalid[b, w - 1 - g:w - 1] = True
            prefix[b, -1] = seq[b, seq_len[b] - 1]
            pvalid[b, -1] = bool(active[b])
        return prefix, pvalid, gap

    def _ensure_capacity(self, m: str, request_id: str, needed: int,
                         seq, seq_len, max_len) -> None:
        """Guard against physical buffer exhaustion: defragment masked holes
        (beyond-paper) and, as a last resort, rebuild the state from the
        committed stream.  Without this, dynamic_update_slice would CLAMP
        out-of-range appends and silently corrupt the cache."""
        sid = StateManager.key(m, request_id)
        st = self.states.get(sid)
        if int(st.write_ptr) + needed <= st.capacity:
            return
        self.states.maybe_defragment(sid, force=True)
        self.profiler.count(f"defrag.{m}")
        st = self.states.get(sid)
        if int(st.write_ptr) + needed <= st.capacity:
            return
        self.states.release(sid)
        self._prefill_model(m, request_id, seq, seq_len, max_len)
        self.profiler.count(f"reprefill.{m}")

    # ------------------------------------------------------------------
    def generate(self, prompt: np.ndarray, prompt_lens: np.ndarray,
                 max_new_tokens, request_id: str = "req0",
                 capacity_margin: int = 4) -> GenerationResult:
        B, Tp = prompt.shape
        budget = (np.full(B, max_new_tokens, np.int64)
                  if np.isscalar(max_new_tokens)
                  else np.asarray(max_new_tokens, np.int64))
        max_new = int(budget.max())
        W_max = max(self.scheduler.windows)
        # physical capacity: prompt + worst-case appended blocks
        max_len = Tp + (max_new + 2) * 2 + self.gcap + \
            (W_max + self.scheduler.max_chain_len) * capacity_margin

        seq = np.zeros((B, max_len + 8), np.int32)
        seq[:, :Tp] = prompt
        seq_len = prompt_lens.astype(np.int64).copy()
        active = np.ones((B,), bool)

        # --- prefill every pool model; probe pairwise similarity (§4.1) --
        import time as _time
        t0 = _time.perf_counter()
        probe: Dict[str, np.ndarray] = {}
        for m in self.pool.names():
            probe[m] = self._prefill_model(m, request_id, seq, seq_len,
                                           max_len)
        self.sims.update_many(pairwise_dtv(probe))
        prefill_wall = _time.perf_counter() - t0

        chain_history, acc_lens = [], []
        cycle_wall, commits_hist = [], []
        committed = 0
        steps = 0
        choice: Optional[ChainChoice] = None
        while active.any() and committed < max_new * B:
            if choice is None or (self.adaptive
                                  and steps % self.reschedule_every == 0):
                if self.fixed_chain is not None:
                    choice = ChainChoice(
                        self.fixed_chain, self.fixed_window or 4, 0.0)
                else:
                    choice = self.scheduler.get_optimal_chain()
            chain, W = choice.chain, choice.window
            chain_history.append((chain, W))

            tc = _time.perf_counter()
            n_acc = self._one_cycle(chain, W, request_id, seq, seq_len,
                                    active)
            cycle_wall.append(_time.perf_counter() - tc)
            commits_hist.append(n_acc.copy())
            acc_lens.append(float(np.mean(n_acc[active])) if active.any()
                            else 0.0)
            committed += int(n_acc.sum())
            steps += 1

            # termination per row (per-row budgets; over-committed tokens
            # in the final cycle are truncated — the prefix still equals
            # target-only output, so equivalence is preserved)
            for b in range(B):
                if not active[b]:
                    continue
                if seq_len[b] - prompt_lens[b] >= budget[b]:
                    seq_len[b] = prompt_lens[b] + budget[b]
                    active[b] = False
                if self.eos >= 0:
                    row = seq[b, prompt_lens[b]:seq_len[b]]
                    hits = np.where(row == self.eos)[0]
                    if hits.size:
                        seq_len[b] = prompt_lens[b] + hits[0] + 1
                        active[b] = False
            if steps > max_new * 4 + 16:   # safety net
                break

        self.states.release_request(request_id)
        seqs = [seq[b, :seq_len[b]].copy() for b in range(B)]
        gens = [seq[b, prompt_lens[b]:seq_len[b]].copy() for b in range(B)]
        return GenerationResult(seqs, gens, steps,
                                int(sum(len(g) for g in gens)),
                                chain_history, acc_lens,
                                prefill_wall_s=prefill_wall,
                                cycle_wall_s=cycle_wall,
                                commits_per_cycle=commits_hist)

    # ------------------------------------------------------------------
    def _one_cycle(self, chain: Tuple[str, ...], W: int, request_id: str,
                   seq: np.ndarray, seq_len: np.ndarray,
                   active: np.ndarray) -> np.ndarray:
        """Execute one speculative cycle; mutates seq/seq_len in place.
        Returns per-row committed token count."""
        B = seq.shape[0]
        max_len = self.states.get(
            StateManager.key(self.target, request_id)).capacity

        # --- ensure chain members are synced (or re-prefill laggards) ----
        prefixes = {}
        for m in chain:
            needed = self.gcap + 2 + W + len(chain)
            self._ensure_capacity(m, request_id, needed, seq, seq_len,
                                  max_len)
            pfx, pval, gap = self._gap_prefix(m, request_id, seq, seq_len,
                                              active)
            if pfx is None:   # fell too far behind -> catch-up prefill
                self.states.release(StateManager.key(m, request_id))
                self._prefill_model(m, request_id, seq, seq_len, max_len)
                pfx, pval, gap = self._gap_prefix(m, request_id, seq,
                                                  seq_len, active)
            prefixes[m] = (pfx, pval)

        # --- target-only chain: plain autoregressive step -----------------
        if len(chain) == 1:
            pfx, pval = prefixes[self.target]
            toks, _probs = self.executor.draft(DraftRequest(
                model=self.target, request_id=request_id,
                prefix_tokens=pfx, prefix_valid=pval, window=1,
                active=active, greedy=self.greedy,
                temperature=self.temperature, rng=self._next_rng()))
            nxt = toks[:, 0]
            n_committed = np.where(active, 1, 0)
            for b in range(B):
                if active[b]:
                    seq[b, seq_len[b]] = nxt[b]
                    seq_len[b] += 1
            return n_committed

        # --- draft --------------------------------------------------------
        m1 = chain[0]
        pfx, pval = prefixes[m1]
        cand, cprobs = self.executor.draft(DraftRequest(
            model=m1, request_id=request_id, prefix_tokens=pfx,
            prefix_valid=pval, window=W, active=active, greedy=self.greedy,
            temperature=self.temperature, rng=self._next_rng()))
        valid_len = np.full((B,), W, np.int32)

        # --- staged verification (levels 2..N) -----------------------------
        ks: List[np.ndarray] = []
        producer = m1
        res = None
        for j, m in enumerate(chain[1:], start=2):
            pfx, pval = prefixes[m]
            res = self.executor.verify(VerifyRequest(
                model=m, request_id=request_id, prefix_tokens=pfx,
                prefix_valid=pval, candidates=cand,
                candidate_probs=cprobs, valid_len=valid_len, active=active,
                greedy=self.greedy, temperature=self.temperature,
                rng=self._next_rng()))
            ks.append(np.asarray(res.num_accepted))
            # similarity feedback (Eq. 5/6) between adjacent chain levels
            if active.any():
                self.sims.update(producer, m,
                                 float(np.mean(res.dtv[active])))
            self.profiler.count(f"accept.{producer}->{m}",
                                float(np.sum(res.num_accepted[active])))
            if m != chain[-1]:
                cand_j, cprobs_j, vlen = ver.splice_candidates(
                    jax.numpy.asarray(cand),
                    jax.numpy.asarray(cprobs) if cprobs is not None else None,
                    jax.tree.map(jax.numpy.asarray, res))
                cand = np.asarray(cand_j)
                cprobs = np.asarray(cprobs_j) if cprobs_j is not None else None
                valid_len = np.asarray(vlen)
            producer = m

        k_N = np.asarray(res.num_accepted)          # target acceptance
        next_token = np.asarray(res.next_token)

        # --- consensus rollback (paper §4.3 RollbackProcessor) -------------
        # level j in [1..N-1] holds a candidate of length W + (j-1);
        # consensus_j = min(k_j, ..., k_N) in shared position coordinates.
        ks_arr = np.stack(ks, axis=0)               # (N-1, B)
        for j, m in enumerate(chain[:-1], start=1):
            tc_j = W + (j - 1)
            consensus = ks_arr[j - 1:].min(axis=0)
            r = np.where(active, tc_j - np.minimum(consensus, tc_j), 0)
            self.executor.rollback(RollbackRequest(
                model=m, request_id=request_id, r=r.astype(np.int32)))
        # target rolls back its own rejects
        self.executor.rollback(RollbackRequest(
            model=chain[-1], request_id=request_id,
            r=np.asarray(res.rollback, np.int32)))

        # --- commit ---------------------------------------------------------
        n_committed = np.where(active, k_N + 1, 0)
        for b in range(B):
            if not active[b]:
                continue
            kb = int(k_N[b])
            seq[b, seq_len[b]:seq_len[b] + kb] = cand[b, :kb]
            seq[b, seq_len[b] + kb] = next_token[b]
            seq_len[b] += kb + 1
        self.profiler.count("cycles")
        self.profiler.count("committed", float(n_committed.sum()))
        return n_committed
