"""Predictive similarity metrics (paper §4.2, Eq. 5/6) and the
SimScore -> acceptance-probability mapping α_ij ≈ f(SimScore).

DTV observations arrive from two sources:
  1. online — every verification step compares verifier probs p against the
     candidate producer probs q (free, uses the verify pass's own tensors);
  2. probes — at prefill (and periodically), every pool model scores the
     same context and all pairwise DTVs are measured (paper §4.1 "initial
     logits used by the scheduler for baseline similarity calculations").
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, Optional, Tuple

import numpy as np

from .profiler import EMA


def pairwise_dtv_rows(
        probs: Dict[str, np.ndarray]) -> Dict[Tuple[str, str], np.ndarray]:
    """probs: model -> (B, V) distribution on the same contexts.
    Returns per-row DTVs (B,) per unordered pair — callers that track
    per-slot similarity (slot-level routing) consume the rows; the scalar
    ``pairwise_dtv`` is the batch mean."""
    out = {}
    for a, b in itertools.combinations(sorted(probs), 2):
        d = 0.5 * np.sum(np.abs(probs[a].astype(np.float64)
                                - probs[b].astype(np.float64)), axis=-1)
        out[(a, b)] = d
    return out


def pairwise_dtv(probs: Dict[str, np.ndarray]) -> Dict[Tuple[str, str], float]:
    """probs: model -> (B, V) distribution on the same contexts."""
    return {k: float(np.mean(v))
            for k, v in pairwise_dtv_rows(probs).items()}


class SimilarityStore:
    """EMA of E[DTV(p_i, p_j)] per unordered model pair (Eq. 6)."""

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self._dtv: Dict[Tuple[str, str], EMA] = {}

    @staticmethod
    def _key(a: str, b: str) -> Tuple[str, str]:
        return (a, b) if a <= b else (b, a)

    def update(self, a: str, b: str, dtv: float):
        k = self._key(a, b)
        self._dtv.setdefault(k, EMA(self.alpha)).update(float(dtv))

    def update_many(self, d: Dict[Tuple[str, str], float]):
        for (a, b), v in d.items():
            self.update(a, b, v)

    def sim_score(self, a: str, b: str, default_dtv: float = 0.9) -> float:
        """SimScore = 1 - E[DTV] (Eq. 6). Unobserved pairs default to
        pessimistic (high-DTV) so the scheduler prefers measured routes
        until probes fill the table."""
        if a == b:
            return 1.0
        k = self._key(a, b)
        e = self._dtv.get(k)
        return 1.0 - (e.get(default_dtv) if e else default_dtv)

    def observed(self, a: str, b: str) -> bool:
        return self._key(a, b) in self._dtv

    def table(self) -> Dict[Tuple[str, str], float]:
        return {k: 1.0 - e.get() for k, e in self._dtv.items()}


class SlotSimilarity:
    """Per-slot DTV EMAs layered over the global ``SimilarityStore``.

    Slot-level routing (§4.2 applied per request): each serving slot keeps
    its OWN acceptance evidence — the admission-time probe over its chain
    members plus the per-row DTV of every verify pass it rides — so
    ``get_optimal_chain(slot)`` can route an easy request through a deep
    chain while a hard one in the next slot stays target-only.  The global
    store is the shared prior: pairs the slot has never observed fall back
    to the pool-wide EMA, and pairs nobody has observed return None so the
    scheduler can apply its exploration default.
    """

    def __init__(self, prior: SimilarityStore, alpha: float = 0.3):
        self.prior = prior
        self.alpha = alpha
        self._dtv: Dict[str, Dict[Tuple[str, str], EMA]] = {}

    def update(self, slot: str, a: str, b: str, dtv: float):
        k = SimilarityStore._key(a, b)
        self._dtv.setdefault(slot, {}).setdefault(
            k, EMA(self.alpha)).update(float(dtv))

    def sim_score(self, slot: Optional[str], a: str, b: str
                  ) -> Optional[float]:
        """Slot's own EMA -> global prior -> None (never observed)."""
        if a == b:
            return 1.0
        if slot is not None:
            e = self._dtv.get(slot, {}).get(SimilarityStore._key(a, b))
            if e is not None:
                return 1.0 - e.get()
        if self.prior.observed(a, b):
            return self.prior.sim_score(a, b)
        return None

    def table(self, slot: str) -> Dict[Tuple[str, str], float]:
        """The slot's OWN observations (prior excluded) — memo inputs."""
        return {k: 1.0 - e.get()
                for k, e in self._dtv.get(slot, {}).items()}

    def release(self, slot: str):
        self._dtv.pop(slot, None)


def acceptance_from_sim(sim: float, calib_a: float = 1.0,
                        calib_b: float = 0.0) -> float:
    """α ≈ f(SimScore) (paper: 'e.g. calibrated sigmoid').

    Theory (Eq. 2): α = E[Σ min(p,q)] = 1 - E[DTV] = SimScore exactly, so the
    default mapping is the identity clipped to [0, 1); ``calib_a/b`` allow a
    logistic recalibration fitted from observed acceptance rates:
        α = sigmoid(calib_a * logit(sim) + calib_b)
    """
    s = min(max(sim, 1e-4), 1 - 1e-4)
    if calib_a == 1.0 and calib_b == 0.0:
        return s
    z = math.log(s / (1 - s))
    return 1.0 / (1.0 + math.exp(-(calib_a * z + calib_b)))
