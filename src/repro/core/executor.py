"""Executor + stateless Processors (paper §3.2, §4.3).

The Executor is the data-plane dispatcher: it receives operation requests
from the ChainRouter, routes them to the specialized processors
(Prefill/Draft/Verify/Rollback, plus Insert/Retire for slot-level
continuous batching and DraftTree/VerifyTree/ResolveTree for
tree-structured speculation), resolves models via the ModelPool and state
via the StateManager, and wraps every call with PerformanceProfiler timing
(the feedback loop of §4.6).

All device computation goes through per-(model, op, shape) jitted callables
cached here; tree programs additionally specialize on the static tree
shape (one compile per (model, branching)).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import verification as ver
from ..kernels import ops as kops
from ..models import kv_cache as kvc
from .model_pool import ModelPool
from .profiler import PerformanceProfiler
from .state_manager import StateManager
from .token_tree import TokenTree


# ---------------------------------------------------------------------------
# Request messages (paper §4.1 "constructs PrefillRequest messages…")
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PrefillRequest:
    model: str
    request_id: str
    tokens: np.ndarray            # (B, Tp) int32
    valid: np.ndarray             # (B, Tp) bool
    max_len: int
    with_snaps: bool = False
    paged: bool = True            # paged KV state (archs that support it)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class DraftRequest:
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1) gap catch-up ++ t_last
    prefix_valid: np.ndarray      # (B, G+1) bool
    window: int
    active: np.ndarray            # (B,) bool
    greedy: bool = True
    temperature: float = 1.0
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class VerifyRequest:
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1)
    prefix_valid: np.ndarray      # (B, G+1)
    candidates: np.ndarray        # (B, Tc)
    candidate_probs: Optional[np.ndarray]  # (B, Tc, V) producer dists
    valid_len: Optional[np.ndarray]        # (B,) legit candidate length
    active: np.ndarray            # (B,)
    greedy: bool = True
    temperature: float = 1.0
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class RollbackRequest:
    model: str
    request_id: str
    r: np.ndarray                 # (B,) int32


@dataclasses.dataclass
class DraftTreeRequest:
    """Tree-structured speculation: draft one token tree (static shape)
    from the last committed token, level by level."""
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1) gap catch-up ++ t_last
    prefix_valid: np.ndarray      # (B, G+1) bool
    tree: TokenTree
    active: np.ndarray            # (B,) bool
    greedy: bool = True
    temperature: float = 1.0
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class VerifyTreeRequest:
    """One merged verify pass over a drafted token tree.  ``node_valid``
    carries upstream pruning (chain levels before this one); ``final``
    marks the target level (sampling mode runs the multi-branch rejection
    walk there instead of per-node prune coins)."""
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1)
    prefix_valid: np.ndarray      # (B, G+1)
    tree: TokenTree
    candidates: np.ndarray        # (B, N) node tokens
    candidate_probs: np.ndarray   # (B, N, V) producer dists
    node_valid: np.ndarray        # (B, N) bool
    active: np.ndarray            # (B,)
    greedy: bool = True
    temperature: float = 1.0
    final: bool = True
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class ResolveTreeRequest:
    """Settle a model's speculative tree block: commit the winning path's
    first ``keep_len`` nodes, mask every dead branch (consensus semantics
    identical to the linear RollbackProcessor)."""
    model: str
    request_id: str
    tree: TokenTree
    path_nodes: np.ndarray        # (B, D) winning root->leaf node ids
    keep_len: np.ndarray          # (B,) int32 — consensus depth to keep
    active: np.ndarray = None     # (B,) bool — rows that appended a tree
                                  # block this cycle (paged states must not
                                  # touch the trailing slots of rows that
                                  # sat the cycle out)


@dataclasses.dataclass
class InsertRequest:
    """Slot-level continuous batching: catch-up prefill of newly admitted
    rows into an EXISTING batch state.  ``valid`` marks the admitted rows'
    real tokens; live rows run as masked no-ops and are untouched."""
    model: str
    request_id: str               # session id (state key namespace)
    tokens: np.ndarray            # (B, T) int32, left-aligned per row
    valid: np.ndarray             # (B, T) bool


class Executor:
    def __init__(self, pool: ModelPool, states: StateManager,
                 profiler: PerformanceProfiler):
        self.pool = pool
        self.states = states
        self.profiler = profiler
        self._jit_cache: Dict[tuple, Any] = {}

    # ---- jitted primitive builders ------------------------------------
    def _fwd(self, model: str, logits_mode: str):
        key = ("fwd", model, logits_mode)
        if key not in self._jit_cache:
            lm = self.pool.model(model)

            @partial(jax.jit, static_argnames=())
            def f(params, state, tokens, valid, extras):
                return lm.decode(params, state, tokens, valid=valid,
                                 logits_mode=logits_mode, **extras)
            self._jit_cache[key] = f
        return self._jit_cache[key]

    def _rollback(self, model: str):
        key = ("rb", model)
        if key not in self._jit_cache:
            lm = self.pool.model(model)
            self._jit_cache[key] = jax.jit(lm.rollback)
        return self._jit_cache[key]

    def _sample(self, greedy: bool, temperature: float):
        key = ("sample", greedy, temperature)
        if key not in self._jit_cache:
            if greedy:
                def s(logits, rng):
                    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
                    return jnp.argmax(logits, -1).astype(jnp.int32), probs
            else:
                def s(logits, rng):
                    lt = logits.astype(jnp.float32) / temperature
                    probs = jax.nn.softmax(lt, -1)
                    return (jax.random.categorical(rng, lt).astype(jnp.int32),
                            probs)
            self._jit_cache[key] = jax.jit(s)
        return self._jit_cache[key]

    # ---- processors ----------------------------------------------------
    def prefill(self, req: PrefillRequest):
        """PrefillProcessor: populate initial ModelState, return last-token
        probs (used for similarity probes) and the state id."""
        lm = self.pool.model(req.model)
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        B = req.tokens.shape[0]
        state, state_axes = lm.make_state(B, req.max_len,
                                          with_snaps=req.with_snaps,
                                          paged=req.paged)
        key = ("prefillop", req.model, req.tokens.shape, req.paged)
        if key not in self._jit_cache:
            def f(params, state, tokens, valid, extras):
                return lm.prefill(params, state, tokens, valid=valid,
                                  logits_mode="last", **extras)
            self._jit_cache[key] = jax.jit(f)
        with self.profiler.timed("prefill", req.model,
                                 tokens=int(req.valid.sum())):
            logits, state = self._jit_cache[key](
                params, state, jnp.asarray(req.tokens),
                jnp.asarray(req.valid), req.extras)
            logits = jax.block_until_ready(logits)
        self.states.create(sid, state, layer_axes=state_axes.layers)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        return np.asarray(probs), sid

    def insert(self, req: InsertRequest):
        """InsertProcessor (continuous batching): feed the admitted rows'
        prompt tokens through the model against the live session state,
        appending their KV/recurrent entries without disturbing occupied
        slots.  Returns (B, V) probs at each row's last valid position —
        the admitted row's distribution doubles as a similarity probe."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        fwd_last = self._fwd(req.model, "last")
        with self.profiler.timed("insert", req.model,
                                 tokens=int(req.valid.sum())):
            logits, state = fwd_last(params, state,
                                     jnp.asarray(req.tokens),
                                     jnp.asarray(req.valid), {})
            logits = jax.block_until_ready(logits)
        self.states.update(sid, state)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        return np.asarray(probs)

    def retire(self, model: str, request_id: str, rows: np.ndarray):
        """RetireProcessor (continuous batching): free finished slot rows of
        a session state (logical release + recurrent-carry wipe)."""
        self.states.free_rows(StateManager.key(model, request_id), rows)

    def _draft_scan(self, model: str, window: int, greedy: bool,
                    temperature: float):
        """Whole-window drafting fused into ONE jitted program: the prefix
        pass + (W-1) decode steps run as a lax.scan, eliminating W host
        round-trips per cycle (§Perf serving-path iteration 1)."""
        key = ("draftscan", model, window, greedy, temperature)
        if key in self._jit_cache:
            return self._jit_cache[key]
        lm = self.pool.model(model)

        def sample(logits, k):
            lt = logits.astype(jnp.float32) / temperature
            probs = jax.nn.softmax(lt, -1)
            if greedy:
                return jnp.argmax(logits, -1).astype(jnp.int32), probs
            return jax.random.categorical(k, lt).astype(jnp.int32), probs

        @jax.jit
        def f(params, state, prefix_tokens, prefix_valid, active, rng):
            logits, state = lm.decode(params, state, prefix_tokens,
                                      valid=prefix_valid & active[:, None],
                                      logits_mode="all")
            rng, k0 = jax.random.split(rng)
            tok0, probs0 = sample(logits[:, -1], k0)

            def step(carry, k):
                state, tok = carry
                lg, state = lm.decode(params, state, tok[:, None],
                                      valid=active[:, None],
                                      logits_mode="all")
                nxt, probs = sample(lg[:, -1], k)
                return (state, nxt), (tok, probs)

            keys = jax.random.split(rng, max(window - 1, 1))
            if window > 1:
                (state, last), (toks, probs) = jax.lax.scan(
                    step, (state, tok0), keys[:window - 1])
                all_toks = jnp.concatenate(
                    [jnp.swapaxes(toks, 0, 1), last[:, None]], axis=1)
                all_probs = jnp.concatenate(
                    [probs0[:, None], jnp.swapaxes(probs, 0, 1)], axis=1)
            else:
                all_toks = tok0[:, None]
                all_probs = probs0[:, None]
            return all_toks, all_probs, state

        self._jit_cache[key] = f
        return f

    def draft(self, req: DraftRequest):
        """DraftProcessor: W speculative tokens from the draft model.

        Returns (draft_tokens (B, W), draft_probs (B, W, V))."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        rng = req.rng if req.rng is not None else jax.random.PRNGKey(0)
        f = self._draft_scan(req.model, req.window, req.greedy,
                             req.temperature)
        import time as _time
        t0 = _time.perf_counter()
        toks, probs, state = f(params, state,
                               jnp.asarray(req.prefix_tokens),
                               jnp.asarray(req.prefix_valid),
                               jnp.asarray(req.active), rng)
        toks = jax.block_until_ready(toks)
        dt = _time.perf_counter() - t0
        # amortized per-token draft time feeds the scheduler's T_i
        self.profiler.record("decode1", req.model, dt / req.window,
                             tokens=req.window)
        self.states.update(sid, state)
        return np.asarray(toks), np.asarray(probs)

    def verify(self, req: VerifyRequest):
        """VerifyProcessor: one forward pass over [gap ++ t_last ++ cand],
        acceptance rule, returns VerifyResult (numpy)."""
        lm = self.pool.model(req.model)
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        fwd_all = self._fwd(req.model, "all")
        G1 = req.prefix_tokens.shape[1]          # gap + 1 (t_last)
        Tc = req.candidates.shape[1]
        active = jnp.asarray(req.active)
        block = np.concatenate([req.prefix_tokens, req.candidates], axis=1)
        bvalid = np.concatenate(
            [req.prefix_valid, np.ones_like(req.candidates, bool)], axis=1)
        bvalid = jnp.asarray(bvalid) & active[:, None]

        with self.profiler.timed("verify", req.model, tokens=Tc,
                                 block=Tc + 1):
            logits, state = fwd_all(params, state, jnp.asarray(block),
                                    bvalid, {})
            logits = jax.block_until_ready(logits)
        self.states.update(sid, state)

        vlogits = logits[:, G1 - 1:]             # (B, Tc+1, V)
        cands = jnp.asarray(req.candidates)
        cprobs = (jnp.asarray(req.candidate_probs)
                  if req.candidate_probs is not None else None)
        key = ("verifymath", req.greedy, vlogits.shape, req.temperature,
               req.valid_len is not None)
        if key not in self._jit_cache:
            if req.greedy:
                self._jit_cache[key] = jax.jit(ver.verify_greedy)
            else:
                self._jit_cache[key] = jax.jit(partial(
                    ver.verify_sampling, temperature=req.temperature))
        if req.greedy:
            res = self._jit_cache[key](cands, vlogits, cprobs, active)
        else:
            res = self._jit_cache[key](
                cands, vlogits, cprobs, req.rng, active=active,
                valid_len=(jnp.asarray(req.valid_len)
                           if req.valid_len is not None else None))
        return jax.tree.map(np.asarray, res)

    def rollback(self, req: RollbackRequest):
        """RollbackProcessor: consensus rollback via StateManager (Eq. 8/9;
        SSM archs restore snapshots first — model.rollback handles both)."""
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        with self.profiler.timed("rollback", req.model,
                                 tokens=int(req.r.sum())):
            state = self._rollback(req.model)(state, jnp.asarray(req.r))
            jax.block_until_ready(state.write_ptr)
        self.states.update(sid, state)

    # ------------------------------------------------------------------
    # Tree-structured speculation processors
    # ------------------------------------------------------------------
    def _draft_tree(self, model: str, tree: TokenTree, greedy: bool,
                    temperature: float):
        """One jitted program drafting the whole tree: the prefix pass plus
        D level expansions (each level decodes all its nodes as one block
        under the static ancestor mask).  Greedy expansion takes every
        parent's top-b children via the fused vocab-tile kernel
        (ops.draft_topk, argmax tie-compatible — branching-factor 1 is
        bit-identical to the linear draft scan); sampling draws children
        i.i.d. from the parent distribution (the multi-branch rejection
        rule assumes independent draws)."""
        key = ("drafttree", model, tree.branching, greedy, temperature)
        if key in self._jit_cache:
            return self._jit_cache[key]
        lm = self.pool.model(model)
        D = tree.depth_levels
        sizes = tree.level_sizes

        @jax.jit
        def f(params, state, prefix_tokens, prefix_valid, active, rng):
            B = prefix_tokens.shape[0]
            logits, state = lm.decode(params, state, prefix_tokens,
                                      valid=prefix_valid & active[:, None],
                                      logits_mode="all")
            par_logits = logits[:, -1:]                  # (B, 1, V)
            toks_all, probs_all = [], []
            for d in range(D):
                n_par = par_logits.shape[1]
                bd = tree.branching[d]
                V = par_logits.shape[-1]
                lt = par_logits.astype(jnp.float32) / temperature
                par_probs = jax.nn.softmax(lt, axis=-1)
                if greedy:
                    _, idx = kops.draft_topk(lt.reshape(B * n_par, V), bd)
                    toks_d = idx.reshape(B, n_par * bd).astype(jnp.int32)
                else:
                    rng, kd = jax.random.split(rng)
                    lt_rep = jnp.repeat(lt, bd, axis=1)  # (B, n_par*bd, V)
                    toks_d = jax.random.categorical(
                        kd, lt_rep, axis=-1).astype(jnp.int32)
                probs_d = jnp.repeat(par_probs, bd, axis=1)
                lg, state = lm.decode(
                    params, state, toks_d,
                    valid=jnp.broadcast_to(active[:, None], toks_d.shape),
                    logits_mode="all",
                    spec_depth=jnp.full((sizes[d],), d, jnp.int32),
                    spec_attend=jnp.asarray(tree.level_attend(d)))
                par_logits = lg
                toks_all.append(toks_d)
                probs_all.append(probs_d)
            return (jnp.concatenate(toks_all, axis=1),
                    jnp.concatenate(probs_all, axis=1), state)

        self._jit_cache[key] = f
        return f

    def draft_tree(self, req: DraftTreeRequest):
        """DraftTreeProcessor: returns (node tokens (B, N), producer dists
        (B, N, V)) in tree-node order."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        rng = req.rng if req.rng is not None else jax.random.PRNGKey(0)
        f = self._draft_tree(req.model, req.tree, req.greedy,
                             req.temperature)
        import time as _time
        t0 = _time.perf_counter()
        toks, probs, state = f(params, state,
                               jnp.asarray(req.prefix_tokens),
                               jnp.asarray(req.prefix_valid),
                               jnp.asarray(req.active), rng)
        toks = jax.block_until_ready(toks)
        dt = _time.perf_counter() - t0
        # per-LEVEL wall time keyed by the full branching profile (meta
        # block -> EMA key): a level forward decodes several sibling
        # nodes, so feeding it into the per-token decode1 EMA would
        # contaminate the linear cost model, and distinct shapes (even
        # with equal node counts) must not share an EMA
        self.profiler.record("decode_level", req.model,
                             dt / req.tree.depth_levels,
                             tokens=req.tree.num_nodes,
                             block=req.tree.branching)
        self.states.update(sid, state)
        return np.asarray(toks), np.asarray(probs)

    def _fwd_tree(self, model: str, tree: TokenTree, prefix_width: int):
        """Jitted verify forward over [gap ++ t_last ++ tree nodes]: the
        prefix part appends linearly, the node part carries depth
        positions and the static ancestor-mask override."""
        key = ("fwdtree", model, tree.branching, prefix_width)
        if key in self._jit_cache:
            return self._jit_cache[key]
        lm = self.pool.model(model)
        N = tree.num_nodes
        spec_depth = jnp.asarray(np.concatenate(
            [np.full(prefix_width, -1, np.int32), tree.depth]))
        spec_attend = jnp.asarray(np.concatenate(
            [np.zeros((prefix_width, N), bool), tree.attend], axis=0))

        @jax.jit
        def f(params, state, tokens, valid):
            return lm.decode(params, state, tokens, valid=valid,
                             logits_mode="all", spec_depth=spec_depth,
                             spec_attend=spec_attend)

        self._jit_cache[key] = f
        return f

    def _verify_tree_math(self, tree: TokenTree, greedy: bool,
                          temperature: float, final: bool):
        key = ("treemath", tree.branching, greedy, temperature, final)
        if key not in self._jit_cache:
            def f(cands, vlogits, node_valid, cprobs, rng, active):
                return ver.verify_tree(
                    tree, cands, vlogits, node_valid,
                    candidate_probs=cprobs, key=rng, greedy=greedy,
                    temperature=temperature, active=active, final=final)
            self._jit_cache[key] = jax.jit(f)
        return self._jit_cache[key]

    def verify_tree(self, req: VerifyTreeRequest):
        """VerifyTreeProcessor: one forward over [gap ++ t_last ++ nodes],
        tree acceptance rule, returns TreeVerifyResult (numpy)."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        G1 = req.prefix_tokens.shape[1]
        N = req.tree.num_nodes
        active = jnp.asarray(req.active)
        block = np.concatenate([req.prefix_tokens, req.candidates], axis=1)
        bvalid = np.concatenate(
            [req.prefix_valid, np.ones_like(req.candidates, bool)], axis=1)
        bvalid = jnp.asarray(bvalid) & active[:, None]
        fwd = self._fwd_tree(req.model, req.tree, G1)
        with self.profiler.timed("verify", req.model, tokens=N,
                                 block=N + 1):
            logits, state = fwd(params, state, jnp.asarray(block), bvalid)
            logits = jax.block_until_ready(logits)
        self.states.update(sid, state)

        vlogits = logits[:, G1 - 1:]                 # (B, N+1, V)
        rng = req.rng if req.rng is not None else jax.random.PRNGKey(0)
        fmath = self._verify_tree_math(req.tree, req.greedy,
                                       req.temperature, req.final)
        res = fmath(jnp.asarray(req.candidates), vlogits,
                    jnp.asarray(req.node_valid),
                    jnp.asarray(req.candidate_probs), rng, active)
        return jax.tree.map(np.asarray, res)

    def _resolve_tree(self, model: str, tree: TokenTree):
        key = ("resolvetree", model, tree.branching)
        if key not in self._jit_cache:
            N, D = tree.num_nodes, tree.depth_levels

            @jax.jit
            def f(state, path_nodes, keep_len, active):
                depth_ok = (jnp.arange(D, dtype=jnp.int32)[None, :]
                            < keep_len[:, None])                   # (B, D)
                onehot = ((path_nodes[..., None]
                           == jnp.arange(N, dtype=jnp.int32)[None, None, :])
                          & depth_ok[..., None])                   # (B, D, N)
                keep = jnp.any(onehot, axis=1)                     # (B, N)
                return kvc.resolve_tree(state, N, keep, keep_len,
                                        active=active)

            self._jit_cache[key] = f
        return self._jit_cache[key]

    def resolve_tree(self, req: ResolveTreeRequest):
        """ResolveTreeProcessor: consensus settle of the model's tree block
        (the tree analogue of RollbackProcessor — mask/table arithmetic
        plus the write-pointer rewind, no data movement)."""
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        # no fallback mask: a paged resolve WITHOUT the active gate would
        # re-mask committed trailing slots of rows that sat the cycle out,
        # so kvc.resolve_tree asserts instead (contiguous states ignore it)
        active = (jnp.asarray(req.active, bool)
                  if req.active is not None else None)
        with self.profiler.timed("rollback", req.model,
                                 tokens=int(req.keep_len.sum())):
            state = self._resolve_tree(req.model, req.tree)(
                state, jnp.asarray(req.path_nodes, jnp.int32),
                jnp.asarray(req.keep_len, jnp.int32), active)
            jax.block_until_ready(state.write_ptr)
        self.states.update(sid, state)
