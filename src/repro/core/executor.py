"""Executor + stateless Processors (paper §3.2, §4.3).

The Executor is the data-plane dispatcher: it receives operation requests
from the ChainRouter, routes them to the specialized processors
(Prefill/Draft/Verify/Rollback, plus Insert/Retire for slot-level
continuous batching and DraftTree/VerifyTree/ResolveTree for
tree-structured speculation), resolves models via the ModelPool and state
via the StateManager, and wraps every call with PerformanceProfiler timing
(the feedback loop of §4.6).

All device computation goes through per-(model, op, shape) jitted callables
cached here; tree programs additionally specialize on the static tree
shape (one compile per (model, branching)).

Fused cycle executor (device-resident speculative cycles): one jitted
program per (chain, window | tree) group runs the ENTIRE cycle on device —
gap catch-up prefixes, the draft scan, every intermediate level's
verify + prune, the final target verify, consensus rollback/resolve, the
commit into device-resident session buffers (seq / seq_len / active), and
per-row budget/EOS termination — with the chain members' model states and
the session buffers donated through ``jax.jit``.  Probabilities never
leave the device; a single small ``FusedSummary`` (the newly committed
token slab, per-level accept counts and DTV rows, per-model cache cursors)
crosses to host in ONE transfer per group per cycle.  The per-op
processors above stay as the bit-exact A/B baseline and as the periodic
profiling path that refreshes the scheduler's per-op timings.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import verification as ver
from ..kernels import ops as kops
from ..models import kv_cache as kvc
from .model_pool import ModelPool
from .profiler import PerformanceProfiler
from .state_manager import StateManager
from .token_tree import TokenTree


# ---------------------------------------------------------------------------
# Request messages (paper §4.1 "constructs PrefillRequest messages…")
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PrefillRequest:
    model: str
    request_id: str
    tokens: np.ndarray            # (B, Tp) int32
    valid: np.ndarray             # (B, Tp) bool
    max_len: int
    with_snaps: bool = False
    paged: bool = True            # paged KV state (archs that support it)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class DraftRequest:
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1) gap catch-up ++ t_last
    prefix_valid: np.ndarray      # (B, G+1) bool
    window: int
    active: np.ndarray            # (B,) bool
    greedy: bool = True
    temperature: float = 1.0
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class VerifyRequest:
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1)
    prefix_valid: np.ndarray      # (B, G+1)
    candidates: np.ndarray        # (B, Tc)
    candidate_probs: Optional[np.ndarray]  # (B, Tc, V) producer dists
    valid_len: Optional[np.ndarray]        # (B,) legit candidate length
    active: np.ndarray            # (B,)
    greedy: bool = True
    temperature: float = 1.0
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class RollbackRequest:
    model: str
    request_id: str
    r: np.ndarray                 # (B,) int32


@dataclasses.dataclass
class DraftTreeRequest:
    """Tree-structured speculation: draft one token tree (static shape)
    from the last committed token, level by level."""
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1) gap catch-up ++ t_last
    prefix_valid: np.ndarray      # (B, G+1) bool
    tree: TokenTree
    active: np.ndarray            # (B,) bool
    greedy: bool = True
    temperature: float = 1.0
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class VerifyTreeRequest:
    """One merged verify pass over a drafted token tree.  ``node_valid``
    carries upstream pruning (chain levels before this one); ``final``
    marks the target level (sampling mode runs the multi-branch rejection
    walk there instead of per-node prune coins)."""
    model: str
    request_id: str
    prefix_tokens: np.ndarray     # (B, G+1)
    prefix_valid: np.ndarray      # (B, G+1)
    tree: TokenTree
    candidates: np.ndarray        # (B, N) node tokens
    candidate_probs: np.ndarray   # (B, N, V) producer dists
    node_valid: np.ndarray        # (B, N) bool
    active: np.ndarray            # (B,)
    greedy: bool = True
    temperature: float = 1.0
    final: bool = True
    rng: Optional[jax.Array] = None


@dataclasses.dataclass
class ResolveTreeRequest:
    """Settle a model's speculative tree block: commit the winning path's
    first ``keep_len`` nodes, mask every dead branch (consensus semantics
    identical to the linear RollbackProcessor)."""
    model: str
    request_id: str
    tree: TokenTree
    path_nodes: np.ndarray        # (B, D) winning root->leaf node ids
    keep_len: np.ndarray          # (B,) int32 — consensus depth to keep
    active: Optional[np.ndarray] = None   # (B,) bool — rows that appended
                                  # a tree block this cycle (paged states
                                  # must not touch the trailing slots of
                                  # rows that sat the cycle out)


@dataclasses.dataclass
class InsertRequest:
    """Slot-level continuous batching: catch-up prefill of newly admitted
    rows into an EXISTING batch state.  ``valid`` marks the admitted rows'
    real tokens; live rows run as masked no-ops and are untouched."""
    model: str
    request_id: str               # session id (state key namespace)
    tokens: np.ndarray            # (B, T) int32, left-aligned per row
    valid: np.ndarray             # (B, T) bool


@dataclasses.dataclass
class FusedCycleRequest:
    """One whole speculative cycle for a (chain, window | tree) group,
    executed as a single jitted program over DEVICE-RESIDENT session
    buffers.  ``gmask`` is the group's slot mask (rows outside ride along
    as no-ops); ``rngs`` carries one key per chain position (draft +
    each verify level) so the session RNG stream advances exactly as the
    per-op path would."""
    chain: Tuple[str, ...]
    request_id: str               # session id (state key namespace)
    window: int
    tree: Optional[TokenTree]     # None = linear window draft
    prefix_width: int             # static gap-prefix width (incl. t_last)
    eos: int                      # EOS token id, -1 = none
    seq: jax.Array                # (B, S) int32 device session buffer
    seq_len: jax.Array            # (B,) int32
    prompt_len: jax.Array         # (B,) int32
    budget: jax.Array             # (B,) int32
    active: jax.Array             # (B,) bool — session-wide live mask
    gmask: jax.Array              # (B,) bool — this group's slots
    rngs: Tuple[jax.Array, ...]   # len(chain) keys
    greedy: bool = True
    temperature: float = 1.0


class FusedSummary(NamedTuple):
    """The ONE device→host transfer of a fused cycle (everything the host
    needs to mirror the device buffers and feed the feedback loops)."""
    slab: jnp.ndarray             # (B, C) newly committed tokens (raw)
    n_committed: jnp.ndarray      # (B,) int32 raw commits (pre-termination)
    new_seq_len: jnp.ndarray      # (B,) int32 post-termination
    new_active: jnp.ndarray       # (B,) bool post-termination
    accepts: jnp.ndarray          # (L-1, B) int32 per-level accepted counts
    dtv: jnp.ndarray              # (L-1, B) f32 per-level DTV rows
    lengths: jnp.ndarray          # (M, B) int32 per-model cache lengths
    write_ptr: jnp.ndarray        # (M, B) int32 per-model append cursors
    free_top: jnp.ndarray         # (M,) int32 paged free blocks (or big)
    num_blocks: jnp.ndarray       # (M, B) int32 paged blocks (contig: 0)


# ---------------------------------------------------------------------------
# Fused-cycle device helpers (pure jnp, traced inside the fused program)
# ---------------------------------------------------------------------------
_BIG = jnp.int32(2 ** 30)     # OOB sentinel for mode="drop" scatters
_NO_POOL = 2 ** 30            # free_top sentinel for contiguous states


def _draft_scan_body(lm, window: int, greedy: bool, temperature: float):
    """The whole-window draft program body (prefix pass + (W-1)-step
    lax.scan).  Shared verbatim by the standalone jitted DraftProcessor and
    the fused cycle program, so both paths run the same math."""
    def sample(logits, k):
        lt = logits.astype(jnp.float32) / temperature
        probs = jax.nn.softmax(lt, -1)
        if greedy:
            return jnp.argmax(logits, -1).astype(jnp.int32), probs
        return jax.random.categorical(k, lt).astype(jnp.int32), probs

    def body(params, state, prefix_tokens, prefix_valid, active, rng):
        logits, state = lm.decode(params, state, prefix_tokens,
                                  valid=prefix_valid & active[:, None],
                                  logits_mode="all")
        rng, k0 = jax.random.split(rng)
        tok0, probs0 = sample(logits[:, -1], k0)

        def step(carry, k):
            state, tok = carry
            lg, state = lm.decode(params, state, tok[:, None],
                                  valid=active[:, None],
                                  logits_mode="all")
            nxt, probs = sample(lg[:, -1], k)
            return (state, nxt), (tok, probs)

        keys = jax.random.split(rng, max(window - 1, 1))
        if window > 1:
            (state, last), (toks, probs) = jax.lax.scan(
                step, (state, tok0), keys[:window - 1])
            all_toks = jnp.concatenate(
                [jnp.swapaxes(toks, 0, 1), last[:, None]], axis=1)
            all_probs = jnp.concatenate(
                [probs0[:, None], jnp.swapaxes(probs, 0, 1)], axis=1)
        else:
            all_toks = tok0[:, None]
            all_probs = probs0[:, None]
        return all_toks, all_probs, state

    return body


def _draft_tree_body(lm, tree: TokenTree, greedy: bool, temperature: float):
    """Whole-tree draft program body (prefix pass + D level expansions),
    shared by the DraftTreeProcessor jit and the fused tree program."""
    D = tree.depth_levels
    sizes = tree.level_sizes

    def body(params, state, prefix_tokens, prefix_valid, active, rng):
        B = prefix_tokens.shape[0]
        logits, state = lm.decode(params, state, prefix_tokens,
                                  valid=prefix_valid & active[:, None],
                                  logits_mode="all")
        par_logits = logits[:, -1:]                  # (B, 1, V)
        toks_all, probs_all = [], []
        for d in range(D):
            n_par = par_logits.shape[1]
            bd = tree.branching[d]
            V = par_logits.shape[-1]
            lt = par_logits.astype(jnp.float32) / temperature
            par_probs = jax.nn.softmax(lt, axis=-1)
            if greedy:
                _, idx = kops.draft_topk(lt.reshape(B * n_par, V), bd)
                toks_d = idx.reshape(B, n_par * bd).astype(jnp.int32)
            else:
                rng, kd = jax.random.split(rng)
                lt_rep = jnp.repeat(lt, bd, axis=1)  # (B, n_par*bd, V)
                toks_d = jax.random.categorical(
                    kd, lt_rep, axis=-1).astype(jnp.int32)
            probs_d = jnp.repeat(par_probs, bd, axis=1)
            lg, state = lm.decode(
                params, state, toks_d,
                valid=jnp.broadcast_to(active[:, None], toks_d.shape),
                logits_mode="all",
                spec_depth=jnp.full((sizes[d],), d, jnp.int32),
                spec_attend=jnp.asarray(tree.level_attend(d)))
            par_logits = lg
            toks_all.append(toks_d)
            probs_all.append(probs_d)
        return (jnp.concatenate(toks_all, axis=1),
                jnp.concatenate(probs_all, axis=1), state)

    return body


def _gap_prefix_dev(state, seq, seq_len, run, width: int):
    """Device analogue of ``ChainRouter._gap_prefix`` with a STATIC width:
    [pads…, gap tokens…, t_last] per row, valid-masked.  Identical valid
    content to the host version (which buckets the width), so the decode
    appends the same logical entries."""
    S = seq.shape[1]
    cache_len = state.length.astype(jnp.int32)
    gap = jnp.where(run, (seq_len - 1) - cache_len, 0)
    cols = jnp.arange(width, dtype=jnp.int32)[None, :]
    off = cols - (width - 1 - gap[:, None])
    gmask = (off >= 0) & (cols < width - 1)
    src = jnp.clip(jnp.where(gmask, cache_len[:, None] + off, 0), 0, S - 1)
    pfx = jnp.where(gmask, jnp.take_along_axis(seq, src, axis=1), 0)
    last = jnp.clip(seq_len - 1, 0, S - 1)
    t_last = jnp.take_along_axis(seq, last[:, None], axis=1)[:, 0]
    pfx = pfx.at[:, -1].set(jnp.where(run, t_last, 0))
    pval = gmask.at[:, -1].set(run)
    return pfx.astype(jnp.int32), pval


def _commit_dev(seq, seq_len, run, cand, k, next_token, slab_width: int):
    """Device analogue of ``ChainRouter._commit_rows``: scatter the
    accepted prefix + correction/bonus into the device ``seq`` buffer.
    Returns (seq, new_seq_len, slab (B, C), n_committed (B,))."""
    B = seq.shape[0]
    j = jnp.arange(slab_width, dtype=jnp.int32)[None, :]
    pad = slab_width - cand.shape[1]
    cand_pad = jnp.concatenate(
        [cand.astype(jnp.int32), jnp.zeros((B, pad), jnp.int32)], axis=1)
    k = k.astype(jnp.int32)
    slab = jnp.where(j < k[:, None], cand_pad, 0)
    slab = jnp.where(j == k[:, None],
                     next_token.astype(jnp.int32)[:, None], slab)
    cnum = jnp.where(run, k + 1, 0).astype(jnp.int32)
    tgt = jnp.where(j < cnum[:, None], seq_len[:, None] + j, _BIG)
    seq = seq.at[jnp.arange(B)[:, None], tgt].set(slab, mode="drop")
    return seq, seq_len + cnum, slab, cnum


def _terminate_dev(slab, run, seq_len_old, new_len, prompt_len,
                   budget, active, eos: int):
    """Device analogue of ``ChainRouter._apply_termination``, bounded to
    this cycle's commit slab: budget clamp first, then the EOS scan up to
    the (possibly clamped) new length.  Rows outside ``run`` keep their
    session values."""
    cap = prompt_len + budget
    over = run & ((new_len - prompt_len) >= budget)
    len1 = jnp.minimum(new_len, cap)
    alive = run & ~over
    if eos >= 0:
        C = slab.shape[1]
        jj = jnp.arange(C, dtype=jnp.int32)[None, :]
        within = jj < (len1 - seq_len_old)[:, None]
        hit = (slab == eos) & within & run[:, None]
        has = jnp.any(hit, axis=1)
        first = jnp.argmax(hit, axis=1).astype(jnp.int32)
        len1 = jnp.where(has, seq_len_old + first + 1, len1)
        alive = alive & ~has
    new_seq_len = jnp.where(run, len1, seq_len_old)
    new_active = jnp.where(run, alive, active)
    return new_seq_len.astype(jnp.int32), new_active


def _wp_rows(st) -> jnp.ndarray:
    wp = st.write_ptr.astype(jnp.int32)
    if wp.ndim == 0:            # contiguous: shared pointer, broadcast
        wp = jnp.broadcast_to(wp[None], (st.batch,))
    return wp


def _free_top_of(st) -> jnp.ndarray:
    ft = getattr(st, "free_top", None)
    if ft is None:
        return jnp.asarray(_NO_POOL, jnp.int32)
    return ft.astype(jnp.int32)


def _num_blocks_of(st) -> jnp.ndarray:
    nb = getattr(st, "num_blocks", None)
    if nb is None:
        return jnp.zeros((st.batch,), jnp.int32)
    return nb.astype(jnp.int32)


def _state_summary(states) -> Tuple[jnp.ndarray, ...]:
    return (jnp.stack([st.length.astype(jnp.int32) for st in states]),
            jnp.stack([_wp_rows(st) for st in states]),
            jnp.stack([_free_top_of(st) for st in states]),
            jnp.stack([_num_blocks_of(st) for st in states]))


class Executor:
    def __init__(self, pool: ModelPool, states: StateManager,
                 profiler: PerformanceProfiler):
        self.pool = pool
        self.states = states
        self.profiler = profiler
        self.placement = pool.placement
        # placement-qualified profiling keys: the scheduler's T_i model is
        # keyed by (model, slice) — the same model on a different slice is
        # a different cost.  Identity on the trivial placement, so every
        # pre-placement EMA key is unchanged.
        self._pq = self.placement.qualify
        # trace-time mesh scope: every jitted program is CALLED (and so
        # first traced) inside this context — the Pallas wrappers in
        # kernels/ops.py replicate their operands only when a mesh is
        # active.  nullcontext on the trivial placement and 1x1 meshes.
        self._mctx = self.placement.mesh_context
        self._jit_cache: Dict[tuple, Any] = {}

    # ---- jitted primitive builders ------------------------------------
    def _fwd(self, model: str, logits_mode: str):
        key = ("fwd", model, logits_mode)
        if key not in self._jit_cache:
            lm = self.pool.model(model)

            @partial(jax.jit, static_argnames=())
            def f(params, state, tokens, valid, extras):
                return lm.decode(params, state, tokens, valid=valid,
                                 logits_mode=logits_mode, **extras)
            self._jit_cache[key] = f
        return self._jit_cache[key]

    def _rollback(self, model: str):
        key = ("rb", model)
        if key not in self._jit_cache:
            lm = self.pool.model(model)
            self._jit_cache[key] = jax.jit(lm.rollback)
        return self._jit_cache[key]

    def _sample(self, greedy: bool, temperature: float):
        key = ("sample", greedy, temperature)
        if key not in self._jit_cache:
            if greedy:
                def s(logits, rng):
                    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
                    return jnp.argmax(logits, -1).astype(jnp.int32), probs
            else:
                def s(logits, rng):
                    lt = logits.astype(jnp.float32) / temperature
                    probs = jax.nn.softmax(lt, -1)
                    return (jax.random.categorical(rng, lt).astype(jnp.int32),
                            probs)
            self._jit_cache[key] = jax.jit(s)
        return self._jit_cache[key]

    # ---- processors ----------------------------------------------------
    def prefill(self, req: PrefillRequest):
        """PrefillProcessor: populate initial ModelState, return last-token
        probs (used for similarity probes) and the state id."""
        lm = self.pool.model(req.model)
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        B = req.tokens.shape[0]
        state, state_axes = lm.make_state(B, req.max_len,
                                          with_snaps=req.with_snaps,
                                          paged=req.paged)
        # allocate the fresh KV state under the member's placement (the
        # same sharding.py rules that placed the params shard the KV block
        # pools); None on the trivial placement — no movement, the legacy
        # single-device path
        sharding = self.placement.state_sharding(req.model, state_axes,
                                                 state)
        if sharding is not None:
            state = jax.device_put(state, sharding)
        key = ("prefillop", req.model, req.tokens.shape, req.paged)
        if key not in self._jit_cache:
            def f(params, state, tokens, valid, extras):
                return lm.prefill(params, state, tokens, valid=valid,
                                  logits_mode="last", **extras)
            self._jit_cache[key] = jax.jit(f)
        with self.profiler.timed("prefill", self._pq(req.model),
                                 tokens=int(req.valid.sum())), self._mctx():
            logits, state = self._jit_cache[key](
                params, state, jnp.asarray(req.tokens),
                jnp.asarray(req.valid), req.extras)
            logits = jax.block_until_ready(logits)
        self.profiler.count("host_sync")
        self.states.create(sid, state, layer_axes=state_axes.layers,
                           sharding=sharding)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        return np.asarray(probs), sid

    def insert(self, req: InsertRequest):
        """InsertProcessor (continuous batching): feed the admitted rows'
        prompt tokens through the model against the live session state,
        appending their KV/recurrent entries without disturbing occupied
        slots.  Returns (B, V) probs at each row's last valid position —
        the admitted row's distribution doubles as a similarity probe."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        fwd_last = self._fwd(req.model, "last")
        with self.profiler.timed("insert", self._pq(req.model),
                                 tokens=int(req.valid.sum())), self._mctx():
            logits, state = fwd_last(params, state,
                                     jnp.asarray(req.tokens),
                                     jnp.asarray(req.valid), {})
            logits = jax.block_until_ready(logits)
        self.profiler.count("host_sync")
        self.states.update(sid, state)
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        return np.asarray(probs)

    def retire(self, model: str, request_id: str, rows: np.ndarray):
        """RetireProcessor (continuous batching): free finished slot rows of
        a session state (logical release + recurrent-carry wipe)."""
        self.states.free_rows(StateManager.key(model, request_id), rows)

    def _req_rng(self, rng: Optional[jax.Array], greedy: bool, op: str):
        """Sampling without an explicit rng is a silent-nondeterminism
        footgun: the old ``PRNGKey(0)`` fallback repeated IDENTICAL draws
        every cycle.  Greedy ops never read the key (a constant stand-in
        is fine); sampling ops must be given the session RNG."""
        if rng is not None:
            return rng
        if not greedy:
            raise ValueError(
                f"{op}: sampling requested without an rng — thread the "
                "session RNG (ChainRouter._next_rng) through the request")
        # speclint: disable=rng-literal-key -- greedy ops never read the
        # key; this constant is a traced-signature stand-in, not a stream
        return jax.random.PRNGKey(0)

    def _draft_scan(self, model: str, window: int, greedy: bool,
                    temperature: float):
        """Whole-window drafting fused into ONE jitted program: the prefix
        pass + (W-1) decode steps run as a lax.scan, eliminating W host
        round-trips per cycle (§Perf serving-path iteration 1).  The body
        is shared with the fused cycle program (``_draft_scan_body``)."""
        key = ("draftscan", model, window, greedy, temperature)
        if key in self._jit_cache:
            return self._jit_cache[key]
        f = jax.jit(_draft_scan_body(self.pool.model(model), window,
                                     greedy, temperature))
        self._jit_cache[key] = f
        return f

    def draft(self, req: DraftRequest):
        """DraftProcessor: W speculative tokens from the draft model.

        Returns (draft_tokens (B, W), draft_probs (B, W, V))."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        rng = self._req_rng(req.rng, req.greedy, "draft")
        f = self._draft_scan(req.model, req.window, req.greedy,
                             req.temperature)
        t0 = time.perf_counter()
        with self._mctx():
            toks, probs, state = f(params, state,
                                   jnp.asarray(req.prefix_tokens),
                                   jnp.asarray(req.prefix_valid),
                                   jnp.asarray(req.active), rng)
        toks = jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        # amortized per-token draft time feeds the scheduler's T_i
        self.profiler.record("decode1", self._pq(req.model),
                             dt / req.window, tokens=req.window)
        self.profiler.count("host_sync")
        self.states.update(sid, state)
        return np.asarray(toks), np.asarray(probs)

    def verify(self, req: VerifyRequest):
        """VerifyProcessor: one forward pass over [gap ++ t_last ++ cand],
        acceptance rule, returns VerifyResult (numpy)."""
        lm = self.pool.model(req.model)
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        fwd_all = self._fwd(req.model, "all")
        G1 = req.prefix_tokens.shape[1]          # gap + 1 (t_last)
        Tc = req.candidates.shape[1]
        active = jnp.asarray(req.active)
        block = np.concatenate([req.prefix_tokens, req.candidates], axis=1)
        bvalid = np.concatenate(
            [req.prefix_valid, np.ones_like(req.candidates, bool)], axis=1)
        bvalid = jnp.asarray(bvalid) & active[:, None]

        t0 = time.perf_counter()
        with self._mctx():
            logits, state = fwd_all(params, state, jnp.asarray(block),
                                    bvalid, {})
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.profiler.record("verify", self._pq(req.model), dt, tokens=Tc,
                             block=Tc + 1)
        # amortized per-token verify time (the decode1 analogue)
        self.profiler.record("verify1", self._pq(req.model), dt / (Tc + 1))
        self.profiler.count("host_sync")
        self.states.update(sid, state)

        vlogits = logits[:, G1 - 1:]             # (B, Tc+1, V)
        cands = jnp.asarray(req.candidates)
        cprobs = (jnp.asarray(req.candidate_probs)
                  if req.candidate_probs is not None else None)
        key = ("verifymath", req.greedy, vlogits.shape, req.temperature,
               req.valid_len is not None)
        if key not in self._jit_cache:
            if req.greedy:
                self._jit_cache[key] = jax.jit(ver.verify_greedy)
            else:
                self._jit_cache[key] = jax.jit(partial(
                    ver.verify_sampling, temperature=req.temperature))
        with self._mctx():
            if req.greedy:
                res = self._jit_cache[key](cands, vlogits, cprobs, active)
            else:
                res = self._jit_cache[key](
                    cands, vlogits, cprobs,
                    self._req_rng(req.rng, req.greedy, "verify"),
                    active=active,
                    valid_len=(jnp.asarray(req.valid_len)
                               if req.valid_len is not None else None))
        return jax.tree.map(np.asarray, res)

    def rollback(self, req: RollbackRequest):
        """RollbackProcessor: consensus rollback via StateManager (Eq. 8/9;
        SSM archs restore snapshots first — model.rollback handles both)."""
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        with self.profiler.timed("rollback", self._pq(req.model),
                                 tokens=int(req.r.sum())), self._mctx():
            state = self._rollback(req.model)(state, jnp.asarray(req.r))
            jax.block_until_ready(state.write_ptr)
        self.profiler.count("host_sync")
        self.states.update(sid, state)

    # ------------------------------------------------------------------
    # Tree-structured speculation processors
    # ------------------------------------------------------------------
    def _draft_tree(self, model: str, tree: TokenTree, greedy: bool,
                    temperature: float):
        """One jitted program drafting the whole tree: the prefix pass plus
        D level expansions (each level decodes all its nodes as one block
        under the static ancestor mask).  Greedy expansion takes every
        parent's top-b children via the fused vocab-tile kernel
        (ops.draft_topk, argmax tie-compatible — branching-factor 1 is
        bit-identical to the linear draft scan); sampling draws children
        i.i.d. from the parent distribution (the multi-branch rejection
        rule assumes independent draws)."""
        key = ("drafttree", model, tree.branching, greedy, temperature)
        if key in self._jit_cache:
            return self._jit_cache[key]
        f = jax.jit(_draft_tree_body(self.pool.model(model), tree,
                                     greedy, temperature))
        self._jit_cache[key] = f
        return f

    def draft_tree(self, req: DraftTreeRequest):
        """DraftTreeProcessor: returns (node tokens (B, N), producer dists
        (B, N, V)) in tree-node order."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        rng = self._req_rng(req.rng, req.greedy, "draft_tree")
        f = self._draft_tree(req.model, req.tree, req.greedy,
                             req.temperature)
        t0 = time.perf_counter()
        with self._mctx():
            toks, probs, state = f(params, state,
                                   jnp.asarray(req.prefix_tokens),
                                   jnp.asarray(req.prefix_valid),
                                   jnp.asarray(req.active), rng)
        toks = jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        # per-LEVEL wall time keyed by the full branching profile (meta
        # block -> EMA key): a level forward decodes several sibling
        # nodes, so feeding it into the per-token decode1 EMA would
        # contaminate the linear cost model, and distinct shapes (even
        # with equal node counts) must not share an EMA
        self.profiler.record("decode_level", self._pq(req.model),
                             dt / req.tree.depth_levels,
                             tokens=req.tree.num_nodes,
                             block=req.tree.branching)
        # amortized per-node draft time (the decode1 analogue for trees)
        self.profiler.record("decode1_tree", self._pq(req.model),
                             dt / req.tree.num_nodes)
        self.profiler.count("host_sync")
        self.states.update(sid, state)
        return np.asarray(toks), np.asarray(probs)

    def _fwd_tree(self, model: str, tree: TokenTree, prefix_width: int):
        """Jitted verify forward over [gap ++ t_last ++ tree nodes]: the
        prefix part appends linearly, the node part carries depth
        positions and the static ancestor-mask override."""
        key = ("fwdtree", model, tree.branching, prefix_width)
        if key in self._jit_cache:
            return self._jit_cache[key]
        lm = self.pool.model(model)
        N = tree.num_nodes
        spec_depth = jnp.asarray(np.concatenate(
            [np.full(prefix_width, -1, np.int32), tree.depth]))
        spec_attend = jnp.asarray(np.concatenate(
            [np.zeros((prefix_width, N), bool), tree.attend], axis=0))

        @jax.jit
        def f(params, state, tokens, valid):
            return lm.decode(params, state, tokens, valid=valid,
                             logits_mode="all", spec_depth=spec_depth,
                             spec_attend=spec_attend)

        self._jit_cache[key] = f
        return f

    def _verify_tree_math(self, tree: TokenTree, greedy: bool,
                          temperature: float, final: bool):
        key = ("treemath", tree.branching, greedy, temperature, final)
        if key not in self._jit_cache:
            def f(cands, vlogits, node_valid, cprobs, rng, active):
                return ver.verify_tree(
                    tree, cands, vlogits, node_valid,
                    candidate_probs=cprobs, key=rng, greedy=greedy,
                    temperature=temperature, active=active, final=final)
            self._jit_cache[key] = jax.jit(f)
        return self._jit_cache[key]

    def verify_tree(self, req: VerifyTreeRequest):
        """VerifyTreeProcessor: one forward over [gap ++ t_last ++ nodes],
        tree acceptance rule, returns TreeVerifyResult (numpy)."""
        params = self.pool.params(req.model)
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        G1 = req.prefix_tokens.shape[1]
        N = req.tree.num_nodes
        active = jnp.asarray(req.active)
        block = np.concatenate([req.prefix_tokens, req.candidates], axis=1)
        bvalid = np.concatenate(
            [req.prefix_valid, np.ones_like(req.candidates, bool)], axis=1)
        bvalid = jnp.asarray(bvalid) & active[:, None]
        fwd = self._fwd_tree(req.model, req.tree, G1)
        t0 = time.perf_counter()
        with self._mctx():
            logits, state = fwd(params, state, jnp.asarray(block), bvalid)
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.profiler.record("verify", self._pq(req.model), dt, tokens=N,
                             block=N + 1)
        # amortized per-node verify time (the decode1 analogue)
        self.profiler.record("verify1", self._pq(req.model), dt / (N + 1))
        self.profiler.count("host_sync")
        self.states.update(sid, state)

        vlogits = logits[:, G1 - 1:]                 # (B, N+1, V)
        rng = self._req_rng(req.rng, req.greedy, "verify_tree")
        fmath = self._verify_tree_math(req.tree, req.greedy,
                                       req.temperature, req.final)
        with self._mctx():
            res = fmath(jnp.asarray(req.candidates), vlogits,
                        jnp.asarray(req.node_valid),
                        jnp.asarray(req.candidate_probs), rng, active)
        return jax.tree.map(np.asarray, res)

    def _resolve_tree(self, model: str, tree: TokenTree):
        key = ("resolvetree", model, tree.branching)
        if key not in self._jit_cache:
            N, D = tree.num_nodes, tree.depth_levels

            @jax.jit
            def f(state, path_nodes, keep_len, active):
                keep = kvc.path_keep_matrix(path_nodes, keep_len, N, D)
                return kvc.resolve_tree(state, N, keep, keep_len,
                                        active=active)

            self._jit_cache[key] = f
        return self._jit_cache[key]

    # ------------------------------------------------------------------
    # Fused cycle executor (device-resident speculative cycles)
    # ------------------------------------------------------------------
    def _build_fused_linear(self, lms, window: int, greedy: bool,
                            temperature: float, P: int, eos: int,
                            reshard=None):
        """One program = one whole LINEAR cycle: gap prefixes for every
        chain member, the draft scan, each level's verify (+ splice), the
        consensus rollback, the commit into the device seq buffer, and
        budget/EOS termination.  Mirrors ``ChainRouter._one_cycle`` op for
        op (the math is the same shared functions), so greedy output is
        bit-exact across paths.

        ``reshard`` (Placement.reshard_between_levels) constrains the
        candidate slab back to replicated at every level boundary, so a
        slab produced on the draft's slice reaches a tensor-parallel
        verifier via XLA collectives INSIDE this one program — never a
        host hop.  None on the trivial placement (identical lowering to
        the unmeshed program); a sharding constraint never changes
        values, so meshed output stays bit-exact where the arithmetic
        itself is unchanged (any mesh, 1x1 guaranteed)."""
        N = len(lms)
        W = window
        C = (W + N - 1) if N >= 2 else 1        # commit slab width
        draft_body = _draft_scan_body(lms[0], W if N >= 2 else 1,
                                      greedy, temperature)
        rs = reshard if reshard is not None else (lambda x: x)

        def f(params, states, seq, seq_len, prompt_len, budget, active,
              gmask, rngs):
            states = list(states)
            B = seq.shape[0]
            run = active & gmask
            sl32 = seq_len.astype(jnp.int32)
            prefixes = [_gap_prefix_dev(st, seq, sl32, run, P)
                        for st in states]
            if N == 1:
                pfx, pval = prefixes[0]
                toks, _probs, st = draft_body(params[0], states[0], pfx,
                                              pval, run, rngs[0])
                states[0] = st
                seq, new_len, slab, cnum = _commit_dev(
                    seq, sl32, run, jnp.zeros((B, 0), jnp.int32),
                    jnp.zeros((B,), jnp.int32), toks[:, 0], C)
                accepts = jnp.zeros((0, B), jnp.int32)
                dtvs = jnp.zeros((0, B), jnp.float32)
            else:
                pfx, pval = prefixes[0]
                cand, cprobs, st = draft_body(params[0], states[0], pfx,
                                              pval, run, rngs[0])
                states[0] = st
                cand, cprobs = rs(cand), rs(cprobs)
                valid_len = jnp.full((B,), W, jnp.int32)
                ks, dts = [], []
                res = None
                for j in range(1, N):
                    vpfx, vpval = prefixes[j]
                    block = jnp.concatenate([vpfx, cand], axis=1)
                    bvalid = jnp.concatenate(
                        [vpval, jnp.ones(cand.shape, bool)],
                        axis=1) & run[:, None]
                    logits, st = lms[j].decode(params[j], states[j], block,
                                               valid=bvalid,
                                               logits_mode="all")
                    states[j] = st
                    vlogits = logits[:, P - 1:]
                    if greedy:
                        res = ver.verify_greedy(cand, vlogits, cprobs, run)
                    else:
                        res = ver.verify_sampling(
                            cand, vlogits, cprobs, rngs[j],
                            temperature=temperature, active=run,
                            valid_len=valid_len)
                    ks.append(res.num_accepted)
                    dts.append(res.dtv)
                    if j < N - 1:
                        cand, cprobs, valid_len = ver.splice_candidates(
                            cand, cprobs, res)
                        cand, cprobs = rs(cand), rs(cprobs)
                k_n = ks[-1]
                ks_arr = jnp.stack(ks)                   # (N-1, B)
                rbs = ver.consensus_rollbacks(ks_arr, W, run)
                for j in range(N - 1):
                    states[j] = lms[j].rollback(states[j], rbs[j])
                states[N - 1] = lms[N - 1].rollback(
                    states[N - 1], res.rollback.astype(jnp.int32))
                seq, new_len, slab, cnum = _commit_dev(
                    seq, sl32, run, cand, k_n, res.next_token, C)
                accepts = ks_arr.astype(jnp.int32)
                dtvs = jnp.stack(dts).astype(jnp.float32)
            new_seq_len, new_active = _terminate_dev(
                slab, run, sl32, new_len,
                prompt_len.astype(jnp.int32), budget.astype(jnp.int32),
                active, eos)
            lengths, wps, fts, nbs = _state_summary(states)
            summary = FusedSummary(slab, cnum, new_seq_len, new_active,
                                   accepts, dtvs, lengths, wps, fts, nbs)
            return tuple(states), seq, new_seq_len, new_active, summary

        return f

    def _build_fused_tree(self, lms, tree: TokenTree, greedy: bool,
                          temperature: float, P: int, eos: int,
                          reshard=None):
        """One program = one whole TREE cycle (draft tree, per-level prune,
        merged target verify, consensus resolve, commit, termination) —
        mirrors ``ChainRouter._one_tree_cycle``.  ``reshard`` as in
        ``_build_fused_linear``: the node slab is constrained back to
        replicated at level boundaries under a real mesh."""
        N = len(lms)
        NT, D = tree.num_nodes, tree.depth_levels
        C = D + 1
        draft_body = _draft_tree_body(lms[0], tree, greedy, temperature)
        rs = reshard if reshard is not None else (lambda x: x)
        spec_depth = jnp.asarray(np.concatenate(
            [np.full(P, -1, np.int32), tree.depth]))
        spec_attend = jnp.asarray(np.concatenate(
            [np.zeros((P, NT), bool), tree.attend], axis=0))

        def f(params, states, seq, seq_len, prompt_len, budget, active,
              gmask, rngs):
            states = list(states)
            B = seq.shape[0]
            run = active & gmask
            sl32 = seq_len.astype(jnp.int32)
            prefixes = [_gap_prefix_dev(st, seq, sl32, run, P)
                        for st in states]
            pfx, pval = prefixes[0]
            cand, cprobs, st = draft_body(params[0], states[0], pfx, pval,
                                          run, rngs[0])
            states[0] = st
            cand, cprobs = rs(cand), rs(cprobs)
            node_valid = jnp.broadcast_to(run[:, None], (B, NT))
            acc_mats, ks, dts = [], [], []
            res = None
            for j in range(1, N):
                final = j == N - 1
                vpfx, vpval = prefixes[j]
                block = jnp.concatenate([vpfx, cand], axis=1)
                bvalid = jnp.concatenate(
                    [vpval, jnp.ones(cand.shape, bool)],
                    axis=1) & run[:, None]
                logits, st = lms[j].decode(params[j], states[j], block,
                                           valid=bvalid, logits_mode="all",
                                           spec_depth=spec_depth,
                                           spec_attend=spec_attend)
                states[j] = st
                vlogits = logits[:, P - 1:]
                res = ver.verify_tree(tree, cand, vlogits, node_valid,
                                      candidate_probs=cprobs, key=rngs[j],
                                      greedy=greedy,
                                      temperature=temperature, active=run,
                                      final=final)
                acc_mats.append(res.accept)
                ks.append(res.num_accepted)
                dts.append(res.dtv)
                if not final:
                    node_valid = node_valid & res.accept
            k_n = res.num_accepted
            path = res.path_nodes
            keeps = ver.tree_consensus_keep(acc_mats, path, k_n, run)
            for j in range(N):
                keep = kvc.path_keep_matrix(path, keeps[j], NT, D)
                states[j] = kvc.resolve_tree(states[j], NT, keep, keeps[j],
                                             active=run)
            path_tokens = jnp.take_along_axis(cand, path, axis=1)
            seq, new_len, slab, cnum = _commit_dev(
                seq, sl32, run, path_tokens, k_n, res.next_token, C)
            new_seq_len, new_active = _terminate_dev(
                slab, run, sl32, new_len,
                prompt_len.astype(jnp.int32), budget.astype(jnp.int32),
                active, eos)
            lengths, wps, fts, nbs = _state_summary(states)
            summary = FusedSummary(slab, cnum, new_seq_len, new_active,
                                   jnp.stack(ks).astype(jnp.int32),
                                   jnp.stack(dts).astype(jnp.float32),
                                   lengths, wps, fts, nbs)
            return tuple(states), seq, new_seq_len, new_active, summary

        return f

    def _fused_program(self, chain: Tuple[str, ...], window: int,
                       tree: Optional[TokenTree], greedy: bool,
                       temperature: float, prefix_width: int, eos: int):
        tkey = tree.branching if tree is not None else None
        key = ("fusedcycle", chain, window, tkey, greedy, temperature,
               prefix_width, eos)
        if key in self._jit_cache:
            return self._jit_cache[key]
        lms = [self.pool.model(m) for m in chain]
        # level-boundary reshard (None on the trivial placement): the
        # candidate slab crosses between member slices on DEVICE, inside
        # this one program — the one-transfer-per-cycle contract holds
        # under meshes
        reshard = self.placement.reshard_between_levels()
        if tree is not None:
            body = self._build_fused_tree(lms, tree, greedy, temperature,
                                          prefix_width, eos,
                                          reshard=reshard)
        else:
            body = self._build_fused_linear(lms, window, greedy,
                                            temperature, prefix_width, eos,
                                            reshard=reshard)
        # donate the model states + the seq/seq_len/active session buffers:
        # the cycle replaces them wholesale, so XLA can update in place
        prog = jax.jit(body, donate_argnums=(1, 2, 3, 6))
        self._jit_cache[key] = prog
        return prog

    def fused_cycle(self, req: FusedCycleRequest):
        """FusedCycleProcessor: run one whole speculative cycle for a
        (chain, window | tree) group on device.  Checkout → run (states and
        session buffers donated) → commit; exactly ONE host sync — the
        ``FusedSummary`` device_get — per call.  Returns
        ({seq, seq_len, active} new device buffers, numpy FusedSummary)."""
        sids = [StateManager.key(m, req.request_id) for m in req.chain]
        params = tuple(self.pool.params(m) for m in req.chain)
        prog = self._fused_program(req.chain, req.window, req.tree,
                                   req.greedy, req.temperature,
                                   req.prefix_width, req.eos)
        states = self.states.checkout(sids)
        t0 = time.perf_counter()
        ok = False
        try:
            with self._mctx():
                out = prog(params, tuple(states), req.seq, req.seq_len,
                           req.prompt_len, req.budget, req.active,
                           req.gmask, tuple(req.rngs))
            ok = True
        finally:
            # try/finally, not a broad except: nothing is swallowed and
            # the cleanup also covers KeyboardInterrupt/SystemExit.
            # Trace-time failure: nothing executed, buffers still valid —
            # restore them.  A RUNTIME failure after dispatch (e.g. device
            # OOM) has already consumed the donated buffers; committing
            # deleted arrays would poison every later op with confusing
            # "Array has been deleted" errors, so drop the registry
            # entries instead and let the next access fail cleanly.
            if not ok:
                donated = any(
                    getattr(leaf, "is_deleted", lambda: False)()
                    for st in states for leaf in jax.tree.leaves(st))
                if donated:
                    for sid in sids:
                        self.states.release(sid)
                else:
                    self.states.commit(sids, states)
        new_states, seq, seq_len, active, summary = out
        self.states.commit(sids, list(new_states))
        # speclint: disable=host-sync -- THE sanctioned one-transfer-per-
        # cycle FusedSummary device_get (PR 5 contract; counted below)
        summary = jax.device_get(summary)
        dt = time.perf_counter() - t0
        self.profiler.count("host_sync")
        self.profiler.record("fused_cycle",
                             "+".join(self._pq(m) for m in req.chain), dt,
                             tokens=int(summary.n_committed.sum()))
        return {"seq": seq, "seq_len": seq_len, "active": active}, summary

    def resolve_tree(self, req: ResolveTreeRequest):
        """ResolveTreeProcessor: consensus settle of the model's tree block
        (the tree analogue of RollbackProcessor — mask/table arithmetic
        plus the write-pointer rewind, no data movement)."""
        sid = StateManager.key(req.model, req.request_id)
        state = self.states.get(sid)
        # no fallback mask: a paged resolve WITHOUT the active gate would
        # re-mask committed trailing slots of rows that sat the cycle out,
        # so kvc.resolve_tree asserts instead (contiguous states ignore it)
        active = (jnp.asarray(req.active, bool)
                  if req.active is not None else None)
        with self.profiler.timed("rollback", self._pq(req.model),
                                 tokens=int(req.keep_len.sum())), \
                self._mctx():
            state = self._resolve_tree(req.model, req.tree)(
                state, jnp.asarray(req.path_nodes, jnp.int32),
                jnp.asarray(req.keep_len, jnp.int32), active)
            jax.block_until_ready(state.write_ptr)
        self.profiler.count("host_sync")
        self.states.update(sid, state)
