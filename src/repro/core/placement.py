"""Placement (paper §4.5, DESIGN §3): per-pool-member mesh slices and
NamedSharding trees — a chain is a *placed* object.

The paper places whole models on single GPUs; the TPU/mesh adaptation
instead gives every pool member a *placement kind* over one shared mesh:

  * ``replicated`` — the member's params/KV live whole on every mesh
    device (the natural choice for small drafts: no collectives on the
    latency-critical draft scan);
  * ``tensor``     — tensor-parallel via ``sharding.py``'s decode rules
    (heads/kv_heads/mlp/vocab over the ``"model"`` axis, with the
    divisibility fallback to replication per dim) — the target's kind;
  * ``data``       — batch rows over the ``"data"`` axis (throughput
    serving of mid-chain verifiers).

``Placement.single()`` (the default everywhere) is the TRIVIAL placement:
no mesh, no shardings, ``qualify`` is the identity — every code path that
threads a trivial placement is byte-identical to the pre-placement code.
An explicit 1x1 mesh exercises the full mesh path (device_put with
NamedShardings, with_sharding_constraint resharding inside the fused
cycle) while remaining mathematically identical to the trivial path —
that A/B is the refactor's bit-exactness anchor
(``tests/test_mesh_serving.py``).

Memory accounting: ``charge``/``discharge`` store the EXACT per-device
byte charges taken when a member's params are placed, so ``discharge``
reverses precisely what ``charge`` added — repeated load/unload cycles
return ``usage`` to zero by construction (the old ``DeviceManager``
recomputed byte counts at free time and clamped at zero, silently
masking any mismatch).

Scheduler interaction: ``qualify`` maps a model name to its
placement-qualified profiling key (``"m7b@tensor:2x4"``), so the
scheduler's T_i model is placement-keyed — the same model on a different
slice is a different cost.
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding import RULES, build_sharding, with_decode_rules

KINDS = ("replicated", "tensor", "data")


def parse_mesh(spec: str, devices=None) -> Mesh:
    """``"dxm"`` (e.g. ``"2x4"``) -> a ``("data", "model")`` mesh over the
    first d*m local devices.  ``"8"`` means ``"1x8"``."""
    m = re.fullmatch(r"(?:(\d+)x)?(\d+)", spec.strip())
    if not m:
        raise ValueError(f"bad mesh spec {spec!r} (expected 'dxm')")
    d, mm = int(m.group(1) or 1), int(m.group(2))
    devices = list(devices if devices is not None else jax.devices())
    if d * mm > len(devices):
        raise ValueError(
            f"mesh {d}x{mm} needs {d * mm} devices, have {len(devices)} "
            "(spawn virtual CPU devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devices[:d * mm]).reshape(d, mm),
                ("data", "model"))


class Placement:
    """Per-pool-member mesh placement + NamedSharding factory + exact
    per-device memory accounting.  ``mesh=None`` is the trivial placement
    (single implicit device, no shardings — the legacy serving path)."""

    def __init__(self, mesh: Optional[Mesh] = None,
                 default_kind: str = "replicated"):
        self.mesh = mesh
        self.default_kind = default_kind
        self.kinds: Dict[str, str] = {}
        # exact charges taken per member: name -> {device: bytes}
        self._charges: Dict[str, Dict[Any, int]] = {}
        self.usage: Dict[Any, int] = {}

    # ---- constructors --------------------------------------------------
    @classmethod
    def single(cls) -> "Placement":
        """The trivial placement: every threading site degenerates to the
        unmeshed code path (no device_put, qualify = identity)."""
        return cls(mesh=None)

    @classmethod
    def from_spec(cls, spec, devices=None) -> "Placement":
        """Build from a ``"dxm"`` string, an existing Mesh, or a
        Placement (returned as-is)."""
        if isinstance(spec, Placement):
            return spec
        if isinstance(spec, Mesh):
            return cls(mesh=spec)
        return cls(mesh=parse_mesh(str(spec), devices))

    # ---- basic properties ----------------------------------------------
    @property
    def is_trivial(self) -> bool:
        return self.mesh is None

    @property
    def size(self) -> int:
        return 1 if self.mesh is None else self.mesh.size

    def describe(self) -> str:
        if self.mesh is None:
            return "single"
        return "x".join(str(self.mesh.shape[a])
                        for a in self.mesh.axis_names)

    def __repr__(self) -> str:
        return f"Placement({self.describe()}, kinds={self.kinds})"

    # ---- member assignment ---------------------------------------------
    def assign(self, name: str, kind: str) -> None:
        if kind not in KINDS:
            raise ValueError(f"unknown placement kind {kind!r} "
                             f"(expected one of {KINDS})")
        self.kinds[name] = kind

    def kind(self, name: str) -> str:
        return self.kinds.get(name, self.default_kind)

    def auto_assign(self, capability: Dict[str, float],
                    target: str) -> None:
        """The paper-shaped default: the TARGET is tensor-parallel across
        the mesh (its verify pass dominates FLOPs and memory), every
        draft/intermediate member is replicated (the draft scan is
        latency-critical and small — no collectives on it)."""
        for n in capability:
            self.assign(n, "tensor" if n == target else "replicated")

    # ---- profiling keys --------------------------------------------------
    def qualify(self, name: str) -> str:
        """Placement-qualified profiling/scheduler key.  Identity on the
        trivial placement so every existing EMA key is unchanged."""
        if self.mesh is None:
            return name
        return f"{name}@{self.kind(name)}:{self.describe()}"

    # ---- sharding factories ---------------------------------------------
    def rules_for(self, name: str, cfg: Any = None) -> Dict:
        kind = self.kind(name)
        if kind == "replicated":
            return {}                     # no rule matches -> all P()
        if kind == "data":
            return {"batch": RULES["batch"], "embed": RULES["embed"]}
        r = with_decode_rules(RULES)      # tensor
        # Param q/k/v projections store a FUSED (heads x head_dim) output
        # dim under the "heads"/"kv_heads" label.  Sharding it is only
        # layout-equivalent to head-parallelism when every shard holds
        # WHOLE heads; a partial-head shard splits head_dim, and RoPE's
        # rotate-half then crosses shard boundaries (miscompiled by the
        # CPU SPMD partitioner, and the wrong layout for the attention
        # kernels regardless).  The divisibility fallback cannot see the
        # fusion — the fused dim divides even when the head count does
        # not — so gate on the member's config here.  (State KV caches
        # carry kv_heads UNFUSED, where plain divisibility suffices.)
        if cfg is not None and self.mesh is not None:
            msize = int(dict(self.mesh.shape).get("model", 1))
            if msize > 1:
                nh = getattr(cfg, "num_heads", 0)
                nkv = getattr(cfg, "num_kv_heads", 0)
                if nh and nh % msize:
                    r["heads"] = (tuple(),)
                if nkv and nkv % msize:
                    r["kv_heads"] = (tuple(),)
        return r

    def param_sharding(self, name: str, axes_tree: Any, tree: Any,
                       cfg: Any = None) -> Optional[Any]:
        """NamedSharding tree for a member's params (None when trivial)."""
        if self.mesh is None:
            return None
        return build_sharding(axes_tree, tree, self.mesh,
                              self.rules_for(name, cfg))

    def state_sharding(self, name: str, state_axes: Any,
                       state: Any) -> Optional[Any]:
        """NamedSharding tree for a member's KV/session state.  The state
        axes pytree mirrors the state exactly (kv_cache.make_state /
        paged_state_axes), so the same rule engine shards the KV block
        pools that shards the params."""
        if self.mesh is None:
            return None
        return build_sharding(state_axes, state, self.mesh,
                              self.rules_for(name))

    def replicated_sharding(self) -> Optional[NamedSharding]:
        """Sharding for the shared session buffers (seq/seq_len/active…):
        replicated — every member's slice reads them."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P())

    def mesh_context(self):
        """Trace-time mesh scope.  The Executor traces every program
        inside this context; the Pallas kernel wrappers (kernels/ops.py)
        key their defensive operand replication off the active mesh —
        GSPMD cannot partition an opaque kernel correctly, so its inputs
        must be gathered whole.  nullcontext (no lowering change at all)
        on the trivial placement AND on single-device meshes: a 1-device
        mesh cannot shard anything, so the 1x1 anchor lowers through the
        byte-identical unmeshed kernel path."""
        if self.mesh is None or self.mesh.size == 1:
            return contextlib.nullcontext()
        return self.mesh

    def reshard_between_levels(self) -> Optional[Callable[[Any], Any]]:
        """The fused-cycle level-boundary reshard: candidate tokens/probs
        produced under the draft's placement are constrained back to
        replicated before the next level's verify consumes them — the
        slab moves DEVICE-to-device (an XLA collective inside the one
        program), never through the host.  None on the trivial placement
        (byte-identical lowering to the unmeshed program)."""
        rep = self.replicated_sharding()
        if rep is None:
            return None

        def reshard(x):
            return jax.lax.with_sharding_constraint(x, rep)

        return reshard

    # ---- memory accounting ----------------------------------------------
    def _leaf_bytes(self, leaf, sharding) -> Tuple[Tuple[Any, int], ...]:
        if self.mesh is None or sharding is None:
            dev = jax.devices()[0]
            return ((dev, int(leaf.size) * leaf.dtype.itemsize),)
        shp = sharding.shard_shape(tuple(leaf.shape))
        nb = int(np.prod(shp, dtype=np.int64)) * leaf.dtype.itemsize
        return tuple((d, int(nb)) for d in self.mesh.devices.flat)

    def charge(self, name: str, tree: Any,
               shardings: Optional[Any] = None) -> Dict[Any, int]:
        """Record the exact per-device bytes ``tree`` occupies under
        ``shardings`` and add them to ``usage``.  Re-charging a name
        first discharges the stale entry (idempotent placement)."""
        if name in self._charges:
            self.discharge(name)
        leaves = jax.tree.leaves(tree)
        slvs = (jax.tree.leaves(
                    shardings,
                    is_leaf=lambda s: isinstance(s, NamedSharding))
                if shardings is not None else [None] * len(leaves))
        charges: Dict[Any, int] = {}
        for leaf, s in zip(leaves, slvs):
            for dev, nb in self._leaf_bytes(leaf, s):
                charges[dev] = charges.get(dev, 0) + nb
        self._charges[name] = charges
        for dev, nb in charges.items():
            self.usage[dev] = self.usage.get(dev, 0) + nb
        return charges

    def discharge(self, name: str) -> None:
        """Reverse EXACTLY what ``charge(name, …)`` added (no recompute,
        no clamping — a mismatch would surface as nonzero usage in the
        load/unload invariant test instead of being masked)."""
        for dev, nb in self._charges.pop(name, {}).items():
            self.usage[dev] = self.usage.get(dev, 0) - nb

    def total_usage(self) -> int:
        return sum(self.usage.values())
