"""Speculative verification rules (paper §2.2 step 3, §4.3 VerifyProcessor).

Protocol invariant (used by every model in the chain):
  - a model's committed cache EXCLUDES the most recent committed token
    ``t_last``;
  - a verify pass feeds ``[t_last, c_0, …, c_{T-1}]`` (T+1 tokens) and gets
    logits ``l_0 … l_T`` where ``l_i`` verifies ``c_i`` and ``l_T`` is the
    bonus position;
  - after accepting ``k`` tokens the model commits ``t_last, c_0…c_{k-1}``,
    the correction/bonus becomes the new ``t_last'``, and the state rolls
    back by ``r = T - k`` (paper Eq. 8/9).

Two acceptance rules:
  greedy   — accept iff candidate == argmax(verifier logits); output stream
             is bit-identical to target-only greedy decoding (paper §5
             Output Quality check).
  sampling — Leviathan et al. rejection sampling: accept c_i w.p.
             min(1, p(c_i)/q(c_i)); on rejection resample from
             norm(max(p-q, 0)).  Distribution-preserving.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels.dtv import dtv_probs as _dtv


class VerifyResult(NamedTuple):
    num_accepted: jnp.ndarray    # (B,) int32 — k, accepted candidate prefix
    next_token: jnp.ndarray      # (B,) int32 — correction (k<T) or bonus (k=T)
    next_probs: jnp.ndarray      # (B, V) — distribution next_token was drawn
                                 # from (producer dist for the next level)
    rollback: jnp.ndarray        # (B,) int32 — r = T - k
    dtv: jnp.ndarray             # (B,) float32 — mean TV distance p vs q over
                                 # the block (feeds SimScore, paper Eq. 5/6)


def verify_greedy(candidates: jnp.ndarray,
                  verifier_logits: jnp.ndarray,
                  candidate_probs: Optional[jnp.ndarray] = None,
                  active: Optional[jnp.ndarray] = None) -> VerifyResult:
    """candidates: (B, T); verifier_logits: (B, T+1, V).

    candidate_probs (B, T, V) is optional — used only for the DTV metric.
    active (B,) masks finished rows (their result is a no-op).
    """
    B, T = candidates.shape
    V = verifier_logits.shape[-1]
    preds = jnp.argmax(verifier_logits, axis=-1)            # (B, T+1)
    match = preds[:, :T] == candidates                       # (B, T)
    k = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    next_token = jnp.take_along_axis(preds, k[:, None], axis=1)[:, 0]
    p = jax.nn.softmax(verifier_logits.astype(jnp.float32), axis=-1)
    next_probs = jnp.take_along_axis(
        p, k[:, None, None], axis=1)[:, 0]                   # (B, V)
    if candidate_probs is not None:
        dtv = jnp.mean(_dtv(p[:, :T], candidate_probs.astype(jnp.float32)),
                       axis=-1)
    else:
        dtv = jnp.zeros((B,), jnp.float32)
    r = (T - k).astype(jnp.int32)
    if active is not None:
        k = jnp.where(active, k, 0)
        # inactive rows appended nothing valid -> nothing to roll back
        r = jnp.where(active, r, 0)
        next_token = jnp.where(active, next_token, 0)
    return VerifyResult(k.astype(jnp.int32), next_token.astype(jnp.int32),
                        next_probs, r, dtv)


def verify_sampling(candidates: jnp.ndarray,
                    verifier_logits: jnp.ndarray,
                    candidate_probs: jnp.ndarray,
                    key: jax.Array,
                    temperature: float = 1.0,
                    active: Optional[jnp.ndarray] = None,
                    valid_len: Optional[jnp.ndarray] = None) -> VerifyResult:
    """Leviathan rejection sampling.

    candidate_probs must be the *producer* distribution of each candidate
    token (draft model probs, or the residual distribution a previous
    verifier resampled from).  ``valid_len`` (B,) bounds acceptance to the
    legitimately-produced candidate prefix (multi-level padding beyond a
    prior level's correction token is NOT distribution-faithful and must be
    force-rejected; greedy mode has no such restriction — an accepted
    padding token equals the verifier argmax by construction).
    """
    B, T = candidates.shape
    V = verifier_logits.shape[-1]
    p = jax.nn.softmax(verifier_logits.astype(jnp.float32) / temperature,
                       axis=-1)                              # (B, T+1, V)
    q = candidate_probs.astype(jnp.float32)                  # (B, T, V)
    p_tok = jnp.take_along_axis(p[:, :T], candidates[..., None],
                                axis=-1)[..., 0]             # (B, T)
    q_tok = jnp.take_along_axis(q, candidates[..., None], axis=-1)[..., 0]
    k_u, k_res = jax.random.split(key)
    u = jax.random.uniform(k_u, (B, T))
    accept = u < jnp.minimum(1.0, p_tok / jnp.maximum(q_tok, 1e-20))
    if valid_len is not None:
        accept = accept & (jnp.arange(T, dtype=jnp.int32)[None, :]
                           < valid_len[:, None])
    k = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

    # residual distribution at the rejection position; bonus: p itself
    p_k = jnp.take_along_axis(p, k[:, None, None], axis=1)[:, 0]   # (B, V)
    q_pad = jnp.concatenate([q, jnp.zeros((B, 1, V), q.dtype)], axis=1)
    q_k = jnp.take_along_axis(q_pad, k[:, None, None], axis=1)[:, 0]
    is_bonus = (k == T)[:, None]
    resid = jnp.maximum(p_k - q_k, 0.0)
    resid_sum = jnp.sum(resid, axis=-1, keepdims=True)
    # degenerate residual (p==q exactly) -> fall back to p
    resid = jnp.where(resid_sum > 1e-20, resid / jnp.maximum(resid_sum, 1e-20),
                      p_k)
    next_probs = jnp.where(is_bonus, p_k, resid)
    next_token = jax.random.categorical(
        k_res, jnp.log(jnp.maximum(next_probs, 1e-30)))
    dtv = jnp.mean(_dtv(p[:, :T], q), axis=-1)
    r = (T - k).astype(jnp.int32)
    if active is not None:
        k = jnp.where(active, k, 0)
        r = jnp.where(active, r, 0)
        next_token = jnp.where(active, next_token, 0)
    return VerifyResult(k.astype(jnp.int32), next_token.astype(jnp.int32),
                        next_probs, r, dtv)


# ---------------------------------------------------------------------------
# Tree verification (SpecInfer-style token trees, one merged verify pass)
# ---------------------------------------------------------------------------
class TreeVerifyResult(NamedTuple):
    """Outcome of verifying one speculative token tree.

    Logit-row convention: the verify pass feeds ``[gap…, t_last, node_0 …
    node_{N-1}]`` and keeps rows ``l_0 … l_N`` where ``l_0`` verifies the
    ROOT nodes (it is t_last's next-token distribution) and ``l_{i+1}`` is
    the distribution AFTER node ``i`` (verifies node i's children / is the
    bonus row when node i ends the winning path).
    """
    accept: jnp.ndarray          # (B, N) bool — path-closed per-node accept
    num_accepted: jnp.ndarray    # (B,) int32 — accepted depth k on the path
    path_nodes: jnp.ndarray      # (B, D) int32 — winning root->leaf node ids
    next_token: jnp.ndarray      # (B,) int32 — correction (k<D) / bonus
    next_probs: jnp.ndarray      # (B, V) — dist next_token was drawn from
    dtv: jnp.ndarray             # (B,) float32 — mean TV p vs q over nodes


def _path_closure(attend: jnp.ndarray, match: jnp.ndarray) -> jnp.ndarray:
    """accept[b, i] = every ancestor-or-self of i matched.  attend is the
    tree's static (N, N) ancestor-or-self matrix."""
    return jnp.all(~attend[None] | match[:, None, :], axis=-1)


def _best_path(paths: jnp.ndarray, accept: jnp.ndarray):
    """(L, D) static paths + (B, N) accept -> (k (B,), path_nodes (B, D)).

    The winning path is the deepest accepted root-to-leaf prefix; argmax
    tie-breaks to the first leaf in node order (deterministic)."""
    acc_on_path = jnp.take(accept, paths, axis=1)            # (B, L, D)
    depth_acc = jnp.sum(jnp.cumprod(acc_on_path.astype(jnp.int32), axis=-1),
                        axis=-1)                             # (B, L)
    k = jnp.max(depth_acc, axis=-1).astype(jnp.int32)
    best_leaf = jnp.argmax(depth_acc, axis=-1)
    return k, paths[best_leaf]


def verify_tree(tree, candidates: jnp.ndarray,
                verifier_logits: jnp.ndarray,
                node_valid: jnp.ndarray,
                candidate_probs: Optional[jnp.ndarray] = None,
                key: Optional[jax.Array] = None,
                greedy: bool = True,
                temperature: float = 1.0,
                active: Optional[jnp.ndarray] = None,
                final: bool = True) -> TreeVerifyResult:
    """Verify a drafted token tree in one pass.

    candidates:      (B, N) node tokens (tree-node order)
    verifier_logits: (B, N+1, V) — rows per the TreeVerifyResult convention
    node_valid:      (B, N) — False = pruned by an earlier chain level (or
                     inactive row); pruned nodes are force-rejected
    candidate_probs: (B, N, V) — each node's *producer* distribution (the
                     draft dist of its parent); required for sampling

    greedy — a node is accepted iff its token equals the verifier argmax at
    its parent row and its whole root path is accepted; the committed
    winning path plus the correction/bonus token is bit-identical to
    target-only greedy decoding (at most one child per node can match the
    argmax, so the walk is deterministic).

    sampling (``final=True``) — SpecInfer multi-branch rejection: walk from
    the root; at each level try the surviving children in sibling order,
    accepting child c w.p. min(1, p(c)/q(c)) and deflating the residual
    ``p <- norm(max(p - q, 0))`` after each rejection; when a whole level
    rejects, sample the correction from the final residual.  With i.i.d.
    child draws from q this preserves the target distribution exactly for
    draft->target chains; intermediate-level pruning makes deeper chains
    SpecInfer-style approximate (documented in ARCHITECTURE.md).

    sampling (``final=False``, the per-level *pruner*) — per-node
    independent coins u < min(1, p/q), path-closed; only the accept matrix
    is authoritative (next_token is informational).
    """
    B, N = candidates.shape
    D = int(tree.depth_levels)
    parent_rows = jnp.asarray(tree.parent + 1)               # (N,) logit rows
    attend = jnp.asarray(tree.attend)
    paths = jnp.asarray(tree.paths)
    p_all = jax.nn.softmax(
        verifier_logits.astype(jnp.float32)
        / (1.0 if greedy else temperature), axis=-1)         # (B, N+1, V)

    if greedy:
        preds = jnp.argmax(verifier_logits, axis=-1)         # (B, N+1)
        match = (candidates == preds[:, parent_rows]) & node_valid
        accept = _path_closure(attend, match)
        k, path_nodes = _best_path(paths, accept)
        last = jnp.take_along_axis(
            path_nodes, jnp.clip(k - 1, 0, D - 1)[:, None], axis=1)[:, 0]
        pos = jnp.where(k > 0, last + 1, 0)                  # bonus row
        next_token = jnp.take_along_axis(preds, pos[:, None], axis=1)[:, 0]
        next_probs = jnp.take_along_axis(
            p_all, pos[:, None, None], axis=1)[:, 0]
    elif final:
        accept, k, path_nodes, next_probs = _tree_walk_sampling(
            tree, candidates, p_all, candidate_probs, node_valid, key)
        k_tok, _ = jax.random.split(key)
        next_token = jax.random.categorical(
            k_tok, jnp.log(jnp.maximum(next_probs, 1e-30)))
    else:
        q_tok = jnp.take_along_axis(
            candidate_probs.astype(jnp.float32),
            candidates[..., None], axis=-1)[..., 0]          # (B, N)
        p_par = jnp.take(p_all, parent_rows, axis=1)         # (B, N, V)
        p_tok = jnp.take_along_axis(
            p_par, candidates[..., None], axis=-1)[..., 0]
        u = jax.random.uniform(key, (B, N))
        coin = u < jnp.minimum(1.0, p_tok / jnp.maximum(q_tok, 1e-20))
        accept = _path_closure(attend, coin & node_valid)
        k, path_nodes = _best_path(paths, accept)
        last = jnp.take_along_axis(
            path_nodes, jnp.clip(k - 1, 0, D - 1)[:, None], axis=1)[:, 0]
        pos = jnp.where(k > 0, last + 1, 0)
        next_probs = jnp.take_along_axis(
            p_all, pos[:, None, None], axis=1)[:, 0]
        next_token = jnp.argmax(next_probs, axis=-1).astype(jnp.int32)

    if candidate_probs is not None:
        p_par = jnp.take(p_all, parent_rows, axis=1)         # (B, N, V)
        d = _dtv(p_par, candidate_probs.astype(jnp.float32))  # (B, N)
        nv = node_valid.astype(jnp.float32)
        dtv = (jnp.sum(d * nv, axis=-1)
               / jnp.maximum(jnp.sum(nv, axis=-1), 1.0))
    else:
        dtv = jnp.zeros((B,), jnp.float32)

    if active is not None:
        k = jnp.where(active, k, 0)
        next_token = jnp.where(active, next_token, 0)
        accept = accept & active[:, None]
    return TreeVerifyResult(accept, k.astype(jnp.int32),
                            path_nodes.astype(jnp.int32),
                            next_token.astype(jnp.int32), next_probs, dtv)


def _tree_walk_sampling(tree, cand, p_all, q, node_valid, key):
    """SpecInfer multi-branch rejection walk (vectorized over B, static
    loops over depth x sibling rank).  Returns (accept (B, N) one-hot path
    matrix, k (B,), path_nodes (B, D), final residual/bonus dist (B, V))."""
    B, N = cand.shape
    D = int(tree.depth_levels)
    children = jnp.asarray(tree.children)                    # (N+1, max_b)
    cur = jnp.zeros((B,), jnp.int32)                         # logit row
    p_res = p_all[:, 0]
    k = jnp.zeros((B,), jnp.int32)
    done = jnp.zeros((B,), bool)
    accept = jnp.zeros((B, N), bool)
    keys = jax.random.split(key, sum(tree.branching) + 1)[1:]
    ci = 0
    path = []
    for d in range(D):
        bd = tree.branching[d]
        kids = jnp.take(children, cur, axis=0)[:, :bd]       # (B, bd)
        chosen = jnp.full((B,), -1, jnp.int32)
        for c in range(bd):
            node = kids[:, c]
            tok = jnp.take_along_axis(cand, node[:, None], axis=1)[:, 0]
            nv = jnp.take_along_axis(node_valid, node[:, None], axis=1)[:, 0]
            q_c = jnp.take_along_axis(
                q.astype(jnp.float32), node[:, None, None], axis=1)[:, 0]
            p_tok = jnp.take_along_axis(p_res, tok[:, None], axis=1)[:, 0]
            q_tok = jnp.take_along_axis(q_c, tok[:, None], axis=1)[:, 0]
            u = jax.random.uniform(keys[ci], (B,))
            ci += 1
            open_ = (~done) & (chosen < 0)
            acc = (open_ & nv
                   & (u < jnp.minimum(1.0, p_tok / jnp.maximum(q_tok, 1e-20))))
            chosen = jnp.where(acc, node, chosen)
            # rejected sibling: deflate the residual by its draft mass.
            # Pruned siblings (node_valid False) were never offered a
            # min(1, p/q) trial, so their mass must NOT be deflated.
            rej = open_ & nv & ~acc
            resid = jnp.maximum(p_res - q_c, 0.0)
            rs = jnp.sum(resid, axis=-1, keepdims=True)
            resid = jnp.where(rs > 1e-20, resid / jnp.maximum(rs, 1e-20),
                              p_res)   # degenerate p<=q residual: keep p
            p_res = jnp.where(rej[:, None], resid, p_res)
        adv = chosen >= 0
        # structural placeholder below the stop depth (never committed:
        # resolve_tree only keeps depths < k)
        path.append(jnp.where(adv, chosen, kids[:, 0]))
        accept = accept | (jnp.arange(N, dtype=jnp.int32)[None, :]
                           == chosen[:, None])
        k = k + adv.astype(jnp.int32)
        p_next = jnp.take_along_axis(
            p_all, jnp.maximum(chosen + 1, 0)[:, None, None], axis=1)[:, 0]
        p_res = jnp.where(adv[:, None], p_next, p_res)
        cur = jnp.where(adv, chosen + 1, cur)
        done = done | ~adv
    return accept, k, jnp.stack(path, axis=1), p_res


# ---------------------------------------------------------------------------
# Consensus bookkeeping (paper §4.3 RollbackProcessor) — pure jittable
# functions shared by the per-op cycle (host-orchestrated) and the fused
# cycle program (device-resident), so both paths settle states identically.
# ---------------------------------------------------------------------------
def consensus_rollbacks(ks_arr: jnp.ndarray, window: int,
                        active: jnp.ndarray) -> jnp.ndarray:
    """Per-level rollback lengths for a linear chain.

    ks_arr: (N-1, B) accepted counts per verify level (level j=2..N);
    level j in [1..N-1] holds a candidate of length ``window + (j-1)`` and
    rolls back to min(k_j, …, k_N) in shared position coordinates (the
    paper's 'rollback length … based on consensus').  The target's own
    rollback is ``VerifyResult.rollback``.  Returns (N-1, B) int32."""
    n_lvls = ks_arr.shape[0]
    out = []
    for j in range(1, n_lvls + 1):
        tc_j = window + (j - 1)
        consensus = jnp.min(ks_arr[j - 1:], axis=0)
        out.append(jnp.where(active, tc_j - jnp.minimum(consensus, tc_j), 0))
    return jnp.stack(out).astype(jnp.int32)


def tree_consensus_keep(accepts: Sequence[jnp.ndarray],
                        path_nodes: jnp.ndarray, k_n: jnp.ndarray,
                        active: jnp.ndarray) -> jnp.ndarray:
    """Consensus keep-lengths for a tree cycle: chain position j keeps the
    winning-path prefix that IT and every deeper level accepted (the draft
    at j=0 keeps the min over all levels).

    accepts: per verify level, (B, N) path-closed accept matrices;
    path_nodes: (B, D) target winning path; k_n: (B,) target accepted
    depth.  Returns (len(chain), B) int32 keep lengths, inactive rows 0."""
    counts = []
    for acc in accepts:
        onpath = jnp.take_along_axis(acc.astype(jnp.int32), path_nodes,
                                     axis=1)
        counts.append(jnp.minimum(
            jnp.sum(jnp.cumprod(onpath, axis=1), axis=1), k_n))
    carr = jnp.stack(counts)                      # (N-1, B)
    outs = []
    for j in range(len(accepts) + 1):             # chain positions 0..N-1
        c = jnp.min(carr, axis=0) if j == 0 else jnp.min(carr[j - 1:],
                                                         axis=0)
        outs.append(jnp.where(active, c, 0))
    return jnp.stack(outs).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Candidate assembly between levels
# ---------------------------------------------------------------------------
def splice_candidates(candidates: jnp.ndarray,
                      candidate_probs: Optional[jnp.ndarray],
                      res: VerifyResult) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Build the next level's candidate block from this level's outcome.

    Next candidate = accepted prefix ++ [correction/bonus token] ++ padding.
    Padding positions (beyond k+1) repeat the correction token but are
    DROPPED by later levels automatically because verification truncates at
    the first mismatch only within valid length — we pass the true length
    implicitly by masking probs; for greedy mode padding is harmless because
    positions after the first mismatch never commit.

    Returns (next_candidates (B, T+1), next_probs or None, valid_len (B,)).
    """
    B, T = candidates.shape
    k = res.num_accepted
    idx = jnp.arange(T + 1, dtype=jnp.int32)[None, :]
    cand_pad = jnp.concatenate(
        [candidates, jnp.zeros((B, 1), candidates.dtype)], axis=1)
    next_cand = jnp.where(idx < k[:, None], cand_pad,
                          res.next_token[:, None])
    valid_len = k + 1
    if candidate_probs is None:
        return next_cand, None, valid_len
    V = candidate_probs.shape[-1]
    probs_pad = jnp.concatenate(
        [candidate_probs, jnp.zeros((B, 1, V), candidate_probs.dtype)], axis=1)
    next_probs = jnp.where((idx < k[:, None])[..., None], probs_pad,
                           res.next_probs[:, None, :])
    return next_cand, next_probs, valid_len
