"""Speculative verification rules (paper §2.2 step 3, §4.3 VerifyProcessor).

Protocol invariant (used by every model in the chain):
  - a model's committed cache EXCLUDES the most recent committed token
    ``t_last``;
  - a verify pass feeds ``[t_last, c_0, …, c_{T-1}]`` (T+1 tokens) and gets
    logits ``l_0 … l_T`` where ``l_i`` verifies ``c_i`` and ``l_T`` is the
    bonus position;
  - after accepting ``k`` tokens the model commits ``t_last, c_0…c_{k-1}``,
    the correction/bonus becomes the new ``t_last'``, and the state rolls
    back by ``r = T - k`` (paper Eq. 8/9).

Two acceptance rules:
  greedy   — accept iff candidate == argmax(verifier logits); output stream
             is bit-identical to target-only greedy decoding (paper §5
             Output Quality check).
  sampling — Leviathan et al. rejection sampling: accept c_i w.p.
             min(1, p(c_i)/q(c_i)); on rejection resample from
             norm(max(p-q, 0)).  Distribution-preserving.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class VerifyResult(NamedTuple):
    num_accepted: jnp.ndarray    # (B,) int32 — k, accepted candidate prefix
    next_token: jnp.ndarray      # (B,) int32 — correction (k<T) or bonus (k=T)
    next_probs: jnp.ndarray      # (B, V) — distribution next_token was drawn
                                 # from (producer dist for the next level)
    rollback: jnp.ndarray        # (B,) int32 — r = T - k
    dtv: jnp.ndarray             # (B,) float32 — mean TV distance p vs q over
                                 # the block (feeds SimScore, paper Eq. 5/6)


def _dtv(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """0.5 * sum_v |p - q| over the last axis (paper Eq. 5)."""
    return 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)


def verify_greedy(candidates: jnp.ndarray,
                  verifier_logits: jnp.ndarray,
                  candidate_probs: Optional[jnp.ndarray] = None,
                  active: Optional[jnp.ndarray] = None) -> VerifyResult:
    """candidates: (B, T); verifier_logits: (B, T+1, V).

    candidate_probs (B, T, V) is optional — used only for the DTV metric.
    active (B,) masks finished rows (their result is a no-op).
    """
    B, T = candidates.shape
    V = verifier_logits.shape[-1]
    preds = jnp.argmax(verifier_logits, axis=-1)            # (B, T+1)
    match = preds[:, :T] == candidates                       # (B, T)
    k = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    next_token = jnp.take_along_axis(preds, k[:, None], axis=1)[:, 0]
    p = jax.nn.softmax(verifier_logits.astype(jnp.float32), axis=-1)
    next_probs = jnp.take_along_axis(
        p, k[:, None, None], axis=1)[:, 0]                   # (B, V)
    if candidate_probs is not None:
        dtv = jnp.mean(_dtv(p[:, :T], candidate_probs.astype(jnp.float32)),
                       axis=-1)
    else:
        dtv = jnp.zeros((B,), jnp.float32)
    r = (T - k).astype(jnp.int32)
    if active is not None:
        k = jnp.where(active, k, 0)
        # inactive rows appended nothing valid -> nothing to roll back
        r = jnp.where(active, r, 0)
        next_token = jnp.where(active, next_token, 0)
    return VerifyResult(k.astype(jnp.int32), next_token.astype(jnp.int32),
                        next_probs, r, dtv)


def verify_sampling(candidates: jnp.ndarray,
                    verifier_logits: jnp.ndarray,
                    candidate_probs: jnp.ndarray,
                    key: jax.Array,
                    temperature: float = 1.0,
                    active: Optional[jnp.ndarray] = None,
                    valid_len: Optional[jnp.ndarray] = None) -> VerifyResult:
    """Leviathan rejection sampling.

    candidate_probs must be the *producer* distribution of each candidate
    token (draft model probs, or the residual distribution a previous
    verifier resampled from).  ``valid_len`` (B,) bounds acceptance to the
    legitimately-produced candidate prefix (multi-level padding beyond a
    prior level's correction token is NOT distribution-faithful and must be
    force-rejected; greedy mode has no such restriction — an accepted
    padding token equals the verifier argmax by construction).
    """
    B, T = candidates.shape
    V = verifier_logits.shape[-1]
    p = jax.nn.softmax(verifier_logits.astype(jnp.float32) / temperature,
                       axis=-1)                              # (B, T+1, V)
    q = candidate_probs.astype(jnp.float32)                  # (B, T, V)
    p_tok = jnp.take_along_axis(p[:, :T], candidates[..., None],
                                axis=-1)[..., 0]             # (B, T)
    q_tok = jnp.take_along_axis(q, candidates[..., None], axis=-1)[..., 0]
    k_u, k_res = jax.random.split(key)
    u = jax.random.uniform(k_u, (B, T))
    accept = u < jnp.minimum(1.0, p_tok / jnp.maximum(q_tok, 1e-20))
    if valid_len is not None:
        accept = accept & (jnp.arange(T, dtype=jnp.int32)[None, :]
                           < valid_len[:, None])
    k = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)

    # residual distribution at the rejection position; bonus: p itself
    p_k = jnp.take_along_axis(p, k[:, None, None], axis=1)[:, 0]   # (B, V)
    q_pad = jnp.concatenate([q, jnp.zeros((B, 1, V), q.dtype)], axis=1)
    q_k = jnp.take_along_axis(q_pad, k[:, None, None], axis=1)[:, 0]
    is_bonus = (k == T)[:, None]
    resid = jnp.maximum(p_k - q_k, 0.0)
    resid_sum = jnp.sum(resid, axis=-1, keepdims=True)
    # degenerate residual (p==q exactly) -> fall back to p
    resid = jnp.where(resid_sum > 1e-20, resid / jnp.maximum(resid_sum, 1e-20),
                      p_k)
    next_probs = jnp.where(is_bonus, p_k, resid)
    next_token = jax.random.categorical(
        k_res, jnp.log(jnp.maximum(next_probs, 1e-30)))
    dtv = jnp.mean(_dtv(p[:, :T], q), axis=-1)
    r = (T - k).astype(jnp.int32)
    if active is not None:
        k = jnp.where(active, k, 0)
        r = jnp.where(active, r, 0)
        next_token = jnp.where(active, next_token, 0)
    return VerifyResult(k.astype(jnp.int32), next_token.astype(jnp.int32),
                        next_probs, r, dtv)


# ---------------------------------------------------------------------------
# Candidate assembly between levels
# ---------------------------------------------------------------------------
def splice_candidates(candidates: jnp.ndarray,
                      candidate_probs: Optional[jnp.ndarray],
                      res: VerifyResult) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Build the next level's candidate block from this level's outcome.

    Next candidate = accepted prefix ++ [correction/bonus token] ++ padding.
    Padding positions (beyond k+1) repeat the correction token but are
    DROPPED by later levels automatically because verification truncates at
    the first mismatch only within valid length — we pass the true length
    implicitly by masking probs; for greedy mode padding is harmless because
    positions after the first mismatch never commit.

    Returns (next_candidates (B, T+1), next_probs or None, valid_len (B,)).
    """
    B, T = candidates.shape
    k = res.num_accepted
    idx = jnp.arange(T + 1, dtype=jnp.int32)[None, :]
    cand_pad = jnp.concatenate(
        [candidates, jnp.zeros((B, 1), candidates.dtype)], axis=1)
    next_cand = jnp.where(idx < k[:, None], cand_pad,
                          res.next_token[:, None])
    valid_len = k + 1
    if candidate_probs is None:
        return next_cand, None, valid_len
    V = candidate_probs.shape[-1]
    probs_pad = jnp.concatenate(
        [candidate_probs, jnp.zeros((B, 1, V), candidate_probs.dtype)], axis=1)
    next_probs = jnp.where((idx < k[:, None])[..., None], probs_pad,
                           res.next_probs[:, None, :])
    return next_cand, next_probs, valid_len
