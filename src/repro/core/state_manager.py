"""StateManager (paper §4.4): lifecycle + consistent updates + atomic
rollbacks for per-model ModelStates.

Atomicity note: JAX states are immutable pytrees; every update is
replace-on-success, so a failed processor call can never leave a state
half-mutated — this *is* the paper's atomic-rollback requirement, obtained
structurally rather than via locking.  The ``_lock`` guards the *registry*
itself: every read-modify-write (``free_rows``, ``maybe_defragment``) holds
it end to end, so a concurrent ``update`` can neither interleave between
the read and the write-back nor be silently overwritten by a stale state.

Slot-level continuous batching: a serving session keys ONE batch-B state
per model (``model/session_id``); individual batch rows are *slots* that
are freed (``free_rows``) when a request finishes and re-filled by a
catch-up prefill when a new request is admitted.  ``create`` optionally
records the state's layer-axes pytree so ``free_rows`` can wipe recurrent
per-row carries exactly (named ``"batch"`` axes), not heuristically.

Paged states (``PagedModelState``) free and account capacity in BLOCKS:
``free_rows`` returns a retired row's blocks to the pool in O(1) and
defragmentation is structurally unnecessary (rows cannot leak holes into
each other), so ``maybe_defragment`` is a no-op for them.
"""
from __future__ import annotations

import threading
from typing import Any, Dict

import jax
import numpy as np

from ..models.kv_cache import (PagedModelState, blocks_in_use, fragmentation, defragment, free_rows as _free_rows)


class StateManager:
    def __init__(self, defrag_threshold: float = 0.5):
        self._states: Dict[str, Any] = {}
        self._axes: Dict[str, Any] = {}
        self._shardings: Dict[str, Any] = {}
        self._lock = threading.Lock()
        self.defrag_threshold = defrag_threshold
        self.defrag_count = 0

    def create(self, state_id: str, state, layer_axes: Any = None,
               sharding: Any = None):
        """``sharding`` (a NamedSharding pytree from Placement) places the
        KV block pools / session buffers explicitly on creation; it is
        remembered so mesh-aware callers can re-place a rebuilt state.
        None (the trivial placement) leaves the state exactly where the
        allocating op produced it — the legacy single-device path."""
        if sharding is not None:
            state = jax.device_put(state, sharding)
        with self._lock:
            self._states[state_id] = state
            if layer_axes is not None:
                self._axes[state_id] = layer_axes
            else:
                self._axes.pop(state_id, None)
            if sharding is not None:
                self._shardings[state_id] = sharding
            else:
                self._shardings.pop(state_id, None)

    def sharding(self, state_id: str):
        """The NamedSharding tree a state was created with (None on the
        trivial placement)."""
        with self._lock:
            return self._shardings.get(state_id)

    def get(self, state_id: str):
        with self._lock:
            return self._states[state_id]

    def exists(self, state_id: str) -> bool:
        """Lazy chain membership: a model outside every live slot's chain
        never materializes a session state at all."""
        with self._lock:
            return state_id in self._states

    def update(self, state_id: str, state):
        with self._lock:
            self._states[state_id] = state

    def checkout(self, state_ids):
        """Atomically REMOVE and return several states (fused-cycle entry):
        the fused executor donates the state buffers to its jitted program,
        and a donated buffer must have no surviving reference — popping the
        registry entries guarantees no concurrent reader can touch the
        invalidated arrays mid-cycle.  Pair with ``commit`` (the program's
        outputs on success, the originals on a trace-time failure)."""
        with self._lock:
            return [self._states.pop(s) for s in state_ids]

    def commit(self, state_ids, states):
        """Write back states taken by ``checkout`` (replace-on-success —
        the same atomicity contract as ``update``, for many states)."""
        with self._lock:
            for s, st in zip(state_ids, states):
                self._states[s] = st

    def release(self, state_id: str):
        with self._lock:
            self._states.pop(state_id, None)
            self._axes.pop(state_id, None)
            self._shardings.pop(state_id, None)

    def release_request(self, request_id: str):
        """GC every model's state for a finished request/session."""
        with self._lock:
            for k in [k for k in self._states if k.endswith("/" + request_id)]:
                self._states.pop(k)
                self._axes.pop(k, None)
                self._shardings.pop(k, None)

    def free_rows(self, state_id: str, rows: np.ndarray):
        """Retire slot rows of a session state atomically: the read, the
        per-row release (paged: O(1) block return; contiguous: logical mask
        release + exact recurrent-carry wipe), and the write-back all
        happen under the registry lock."""
        with self._lock:
            st = self._states[state_id]
            self._states[state_id] = _free_rows(st, rows,
                                                self._axes.get(state_id))

    def maybe_defragment(self, state_id: str, force: bool = False) -> bool:
        """Beyond-paper: compact masked holes when fragmentation is high
        (or unconditionally when ``force``, e.g. on capacity pressure).
        Atomic read-modify-write; no-op for paged states (per-row block
        tables cannot fragment across slots)."""
        with self._lock:
            st = self._states[state_id]
            if isinstance(st, PagedModelState):
                return False
            frag = float(fragmentation(st))
            if force or frag > self.defrag_threshold:
                self._states[state_id] = defragment(st)
                self.defrag_count += 1
                return True
            return False

    def lengths(self, state_id: str) -> np.ndarray:
        with self._lock:
            return np.asarray(self._states[state_id].length)

    def row_footprint(self, state_id: str, row: int) -> int:
        """Physical cache entries held by ONE batch row: allocated blocks
        × block size for paged states, the row's cached length for
        contiguous ones.  0 for a missing state — the O(chain) admission
        invariant ('pool models outside the assigned chain hold zero
        rows/blocks for a slot') is asserted against this."""
        with self._lock:
            st = self._states.get(state_id)
        if st is None:
            return 0
        if isinstance(st, PagedModelState):
            return int(np.asarray(st.num_blocks)[row]) * st.block_size
        return int(np.asarray(st.length)[row])

    def capacity_used(self, state_id: str) -> int:
        """Physical occupancy: shared-pointer height for contiguous states,
        in-use pool slots (blocks * block_size) for paged ones."""
        with self._lock:
            st = self._states[state_id]
        if isinstance(st, PagedModelState):
            return int(blocks_in_use(st)) * st.block_size
        return int(st.write_ptr)

    @staticmethod
    def key(model: str, request_id: str) -> str:
        return f"{model}/{request_id}"
