"""StateManager (paper §4.4): lifecycle + consistent updates + atomic
rollbacks for per-model ModelStates.

Atomicity note: JAX states are immutable pytrees; every update is
replace-on-success, so a failed processor call can never leave a state
half-mutated — this *is* the paper's atomic-rollback requirement, obtained
structurally rather than via locking.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..models.kv_cache import ModelState, fragmentation, defragment


class StateManager:
    def __init__(self, defrag_threshold: float = 0.5):
        self._states: Dict[str, ModelState] = {}
        self._lock = threading.Lock()
        self.defrag_threshold = defrag_threshold
        self.defrag_count = 0

    def create(self, state_id: str, state: ModelState):
        with self._lock:
            self._states[state_id] = state

    def get(self, state_id: str) -> ModelState:
        return self._states[state_id]

    def update(self, state_id: str, state: ModelState):
        with self._lock:
            self._states[state_id] = state

    def release(self, state_id: str):
        with self._lock:
            self._states.pop(state_id, None)

    def release_request(self, request_id: str):
        """GC every model's state for a finished request."""
        with self._lock:
            for k in [k for k in self._states if k.endswith("/" + request_id)]:
                self._states.pop(k)

    def maybe_defragment(self, state_id: str, force: bool = False) -> bool:
        """Beyond-paper: compact masked holes when fragmentation is high
        (or unconditionally when ``force``, e.g. on capacity pressure)."""
        st = self._states[state_id]
        frag = float(fragmentation(st))
        if force or frag > self.defrag_threshold:
            self.update(state_id, defragment(st))
            self.defrag_count += 1
            return True
        return False

    def lengths(self, state_id: str) -> np.ndarray:
        return np.asarray(self._states[state_id].length)

    def capacity_used(self, state_id: str) -> int:
        return int(self._states[state_id].write_ptr)

    @staticmethod
    def key(model: str, request_id: str) -> str:
        return f"{model}/{request_id}"
