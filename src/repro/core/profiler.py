"""PerformanceProfiler (paper §4.6): low-overhead wall-time + counter
metrics, EMA-smoothed (paper §4.2 input metrics), feeding the
ModelChainScheduler's adaptive loop.
"""
from __future__ import annotations

import collections
import contextlib
import time
from dataclasses import dataclass, field
from typing import Dict, Optional


class EMA:
    """T_new = a * measured + (1 - a) * T_old (paper §4.2)."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.value: Optional[float] = None
        self.count = 0

    def update(self, x: float) -> float:
        self.value = x if self.value is None else (
            self.alpha * x + (1 - self.alpha) * self.value)
        self.count += 1
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


@dataclass
class OpRecord:
    op: str
    model: str
    wall_s: float
    tokens: int
    meta: dict = field(default_factory=dict)


class PerformanceProfiler:
    """Gathers (op, model) -> EMA wall time; plus counters and a trace.

    Keys used by the scheduler:
      ("decode1", m)        — per-token single-step decode time T_i
      ("decode_level", m, branching) — per-level tree-draft forward time
                              for one tree shape (a level decodes several
                              sibling nodes at once, so it is NOT
                              comparable to decode1, and distinct shapes
                              must not share an EMA)
      ("verify", m, T)      — verify-pass wall time for block length T
      ("prefill", m)        — prefill time (chain-switch catch-up cost)

    Diagnostics-only keys:
      ("verify1", m)        — amortized per-token verify time (dt / (T+1)),
                              the verify analogue of decode1
      ("fused_cycle", c)    — whole fused-cycle wall time per chain group

    Load-signal key (SLO-aware scheduling + admission shed policy):
      ("cycle_wall", "session") — wall time of one whole RouterSession
                              cycle across all sub-cycle groups (query it
                              via ``cycle_time()``); deliberately NOT in
                              the scheduler's Eq. 7 inputs snapshot — the
                              LoadSignal carries it instead

    The ``host_sync`` counter tallies host-synchronizing op dispatches
    (device→host transfers that block on the device): one per per-op
    processor call on the legacy path, ONE per cycle group on the fused
    path — ``benchmarks/cycle_overhead.py`` asserts the gap.
    """

    def __init__(self, alpha: float = 0.3, keep_trace: bool = True,
                 trace_cap: Optional[int] = 4096):
        self.alpha = alpha
        self.emas: Dict[tuple, EMA] = collections.defaultdict(
            lambda: EMA(self.alpha))
        self.counters: Dict[str, float] = collections.defaultdict(float)
        # bounded ring buffer: a long-running serving session records an
        # OpRecord per op forever, so an unbounded list is a memory leak —
        # keep the most recent ``trace_cap`` records (None = unbounded,
        # for short offline analyses that want the full trace)
        self.trace: collections.deque = collections.deque(maxlen=trace_cap)
        self.keep_trace = keep_trace

    @contextlib.contextmanager
    def timed(self, op: str, model: str, tokens: int = 1, **meta):
        t0 = time.perf_counter()
        yield
        dt = time.perf_counter() - t0
        self.record(op, model, dt, tokens, **meta)

    def record(self, op: str, model: str, wall_s: float, tokens: int = 1,
               **meta):
        key = (op, model) + ((meta["block"],) if "block" in meta else ())
        self.emas[key].update(wall_s)
        self.counters[f"{op}.{model}.calls"] += 1
        self.counters[f"{op}.{model}.tokens"] += tokens
        if self.keep_trace:
            self.trace.append(OpRecord(op, model, wall_s, tokens, meta))

    def count(self, name: str, inc: float = 1.0):
        self.counters[name] += inc

    # ---- queries used by the scheduler --------------------------------
    def decode_time(self, model: str, default: float) -> float:
        return self.emas[("decode1", model)].get(default)

    def level_time(self, model: str, branching: tuple,
                   default: float) -> float:
        """Tree-draft per-level forward time for one tree shape (falls
        back to ``default`` — typically the linear decode time — until
        that shape has run a cycle)."""
        return self.emas[("decode_level", model, branching)].get(default)

    def verify_time(self, model: str, block: int,
                    default: float) -> float:
        e = self.emas[("verify", model, block)]
        if e.count > 0:
            return e.get(default)
        # fall back to nearest measured block length
        cands = [(k[2], v) for k, v in self.emas.items()
                 if len(k) == 3 and k[0] == "verify" and k[1] == model
                 and v.count > 0]
        if cands:
            blk, v = min(cands, key=lambda kv: abs(kv[0] - block))
            return v.get(default) * (block / max(blk, 1)) ** 0.5
        return default

    def prefill_time(self, model: str, default: float) -> float:
        return self.emas[("prefill", model)].get(default)

    def cycle_time(self, default: float = 0.0) -> float:
        """EMA wall time of one whole speculative cycle (all sub-cycle
        groups), recorded by ``RouterSession.run_cycle`` under
        ``("cycle_wall", "session")`` — the load signal's estimate of how
        long a queued request waits per cycle boundary (SLO-aware
        scheduling and the admission shed policy both read it)."""
        return self.emas[("cycle_wall", "session")].get(default)

    def summary(self) -> Dict[str, float]:
        out = {}
        for k, e in self.emas.items():
            if e.count:
                out["/".join(map(str, k))] = e.get()
        out.update(self.counters)
        return out
