from .chain_router import ChainRouter, GenerationResult
from .executor import (DraftRequest, DraftTreeRequest, Executor,
                       PrefillRequest, ResolveTreeRequest, RollbackRequest,
                       VerifyRequest, VerifyTreeRequest)
from .model_pool import ModelPool, PoolEntry
from .placement import Placement, parse_mesh
from .profiler import EMA, PerformanceProfiler
from .scheduler import (ChainChoice, LoadSignal, ModelChainScheduler,
                        expected_accepted, expected_tree_accepted)
from .similarity import (SimilarityStore, SlotSimilarity,
                         acceptance_from_sim, pairwise_dtv,
                         pairwise_dtv_rows)
from .state_manager import StateManager
from .token_tree import TokenTree
from . import verification
