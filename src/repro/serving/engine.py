"""Serving engine: batches Poisson-arriving requests and runs them through
the SpecRouter ChainRouter, collecting the paper's §5 metrics
(goodput, request throughput, TTFT, TPOT, EAF, SLO attainment).

Batching model: iteration-level batch formation — requests queue until
``batch_size`` are available (or ``batch_wait_s`` elapses), then the batch
generates to completion.  Per-request TTFT/TPOT are derived from the
router's per-cycle wall times and per-row commit history (a finished row's
later cycles don't bill to it).  This is simpler than slot-level continuous
batching but preserves the paper's measurement semantics; the queueing
delay is fully accounted in TTFT.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import ChainRouter, ModelPool, PerformanceProfiler
from ..data.workload import Request


@dataclasses.dataclass
class ServingMetrics:
    goodput_tps: float
    request_throughput_rps: float
    avg_ttft_s: float
    p95_ttft_s: float
    avg_tpot_s: float
    avg_latency_s: float
    p95_latency_s: float
    slo_attainment: float
    total_tokens: int
    num_requests: int
    makespan_s: float
    avg_acceptance_len: float

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


class ServingEngine:
    def __init__(self, pool: ModelPool, target: str,
                 batch_size: int = 4, batch_wait_s: float = 0.25,
                 slo_latency_s: float = 30.0,
                 router_kwargs: Optional[dict] = None):
        self.pool = pool
        self.target = target
        self.batch_size = batch_size
        self.batch_wait_s = batch_wait_s
        self.slo = slo_latency_s
        self.router_kwargs = router_kwargs or {}
        # one router per engine: jit caches and scheduler state persist
        # across batches (recompiling per batch would bill compilation to
        # every request's latency)
        self._router = ChainRouter(self.pool, self.target,
                                   **self.router_kwargs)

    def run(self, requests: Sequence[Request]) -> ServingMetrics:
        """Simulated-clock execution: arrivals follow the workload trace;
        service time is the REAL wall time of the CPU models."""
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        clock = 0.0
        i = 0
        acc_lens: List[float] = []
        while i < len(reqs):
            batch = [reqs[i]]
            i += 1
            # batch formation: wait for up to batch_size or batch_wait_s
            window_end = max(clock, batch[0].arrival_s) + self.batch_wait_s
            while (i < len(reqs) and len(batch) < self.batch_size
                   and reqs[i].arrival_s <= window_end):
                batch.append(reqs[i])
                i += 1
            start = max(clock, max(r.arrival_s for r in batch))
            acc = self._serve_batch(batch, start)
            acc_lens.extend(acc)
            clock = max(r.finish_s for r in batch)

        done = [r for r in reqs if r.finish_s >= 0]
        total_tokens = sum(r.generated for r in done)
        makespan = max(r.finish_s for r in done) - min(r.arrival_s
                                                       for r in done)
        ttfts = np.array([r.ttft for r in done])
        lats = np.array([r.latency for r in done])
        tpots = np.array([r.tpot for r in done if np.isfinite(r.tpot)])
        return ServingMetrics(
            goodput_tps=total_tokens / makespan,
            request_throughput_rps=len(done) / makespan,
            avg_ttft_s=float(ttfts.mean()),
            p95_ttft_s=float(np.percentile(ttfts, 95)),
            avg_tpot_s=float(tpots.mean()) if tpots.size else float("nan"),
            avg_latency_s=float(lats.mean()),
            p95_latency_s=float(np.percentile(lats, 95)),
            slo_attainment=float(np.mean(lats <= self.slo)),
            total_tokens=total_tokens,
            num_requests=len(done),
            makespan_s=makespan,
            avg_acceptance_len=float(np.mean(acc_lens)) if acc_lens else 0.0,
        )

    # ------------------------------------------------------------------
    def _serve_batch(self, batch: List[Request], start: float) -> List[float]:
        B = len(batch)
        maxlen = max(len(r.prompt) for r in batch)
        prompt = np.zeros((B, maxlen), np.int64)
        lens = np.zeros(B, np.int64)
        for b, r in enumerate(batch):
            prompt[b, :len(r.prompt)] = r.prompt
            lens[b] = len(r.prompt)
            r.start_s = start
        budgets = np.array([r.max_new_tokens for r in batch])

        res = self._router.generate(prompt, lens, max_new_tokens=budgets,
                                    request_id=batch[0].request_id)

        # reconstruct per-request timing from per-cycle commits
        t = start + res.prefill_wall_s
        cum = np.zeros(B, np.int64)
        first_at = np.full(B, -1.0)
        done_at = np.full(B, -1.0)
        budget = np.array([r.max_new_tokens for r in batch])
        gen_len = np.array([len(g) for g in res.generated])
        for wall, commits in zip(res.cycle_wall_s, res.commits_per_cycle):
            t += wall
            newly = (cum == 0) & (commits > 0)
            first_at[newly] = t
            cum += commits
            fin = (done_at < 0) & (cum >= np.minimum(budget, gen_len))
            done_at[fin] = t
        done_at[done_at < 0] = t
        first_at[first_at < 0] = t
        for b, r in enumerate(batch):
            r.first_token_s = first_at[b]
            r.finish_s = done_at[b]
            r.generated = int(gen_len[b])
        return res.acceptance_lengths
