"""Serving engine: schedules Poisson-arriving requests onto the SpecRouter
ChainRouter and collects the paper's §5 metrics (goodput, request
throughput, TTFT, TPOT, EAF, SLO attainment).

Batching model (default): **slot-level continuous batching** — a fixed pool
of ``batch_size`` slots, per-slot request lifecycle

    QUEUED -> PREFILL -> DECODING -> DONE

New requests are admitted into freed slots *between* speculation cycles
(RouterSession.admit catch-up-prefills the new row while live rows run as
masked no-ops) and finished rows retire without stalling the others, so a
long request never blocks the arrivals queued behind it.  This is the
iteration-level scheduling that SLO-aware serving systems (SpecServe,
StreamServe) identify as the main goodput/p95-TTFT lever under load.
Routing is per-slot with lazy chain membership (see core/chain_router.py):
admission materializes a request only in its assigned chain's models —
O(chain) prefill work and KV footprint, not O(pool) — and each cycle runs
one masked sub-cycle per distinct (chain, window, tree) group.  Pass
``router_kwargs=dict(slot_routing=False)`` for the legacy global-chain
baseline (``benchmarks/routing_ab.py`` is the A/B).

Speculation cycles are DEVICE-RESIDENT by default (``fused=True``): each
sub-cycle group is one jitted program and one host transfer per cycle,
with periodic unfused profiling cycles (``router_kwargs["profile_every"]``,
default 16) refreshing the scheduler's per-op timings; ``fused=False``
restores the per-op host-orchestrated loop
(``benchmarks/cycle_overhead.py`` is the A/B).

SLO-aware serving (continuous mode): every request may carry a TTFT/TPOT
SLO (``data/workload.py``; engine-level ``ttft_slo_s``/``tpot_slo_s``
fill unset ones).  When any SLO is configured the scheduler's objective
switches from raw T_eff to predicted SLO attainment — the engine
publishes a ``LoadSignal`` (run-queue depth, slot occupancy, profiler
cycle-latency EMA) before every cycle, and under pressure the chain
search shrinks speculation windows / flattens trees / drops slots to
target-only so queued requests' first tokens are not starved by deep
speculation.  Admission becomes earliest-TTFT-deadline-first (exact FIFO
for no-SLO populations), and ``shed_policy="ttft"`` drops queued
requests whose deadline is already unmeetable.  With no SLOs configured
everything degenerates to the latency-only scheduler bit-exactly
(``tests/test_slo_scheduling.py`` pins this; ``benchmarks/goodput_ab.py``
is the A/B).

Legacy model (``continuous=False``): stop-the-world batch formation —
requests queue until ``batch_size`` are available (or ``batch_wait_s``
elapses), then the batch generates to completion.  Kept as the reproducible
A/B baseline (``benchmarks/run.py --no-continuous``).

Timing semantics (both modes): arrivals follow the workload trace on a
simulated clock; service time is the REAL wall time of the host models.
Queueing delay is fully billed to TTFT — a request's first-token clock
starts at ``arrival_s``, and every admission prefill / speculation cycle
that runs before its first commit advances the clock it waits on.  A
retired slot's later cycles bill nothing to it (``finish_s`` is fixed at
retirement).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core import (ChainRouter, LoadSignal, ModelPool, PerformanceProfiler,
                    Placement)
from ..data.workload import Request

# serving keeps a bounded op trace: the profiler's EMAs/counters (what the
# scheduler reads) are O(1), but OpRecords accumulate per op — a small ring
# is plenty for debugging and cannot leak over a long-running engine
_SERVING_TRACE_CAP = 512


@dataclasses.dataclass
class ServingMetrics:
    goodput_tps: float
    request_throughput_rps: float
    avg_ttft_s: float
    p95_ttft_s: float
    avg_tpot_s: float
    avg_latency_s: float
    p95_latency_s: float
    slo_attainment: float
    total_tokens: int
    num_requests: int
    makespan_s: float
    avg_acceptance_len: float
    avg_queue_s: float = 0.0        # arrival -> slot admission
    # per-request SLO goodput (SpecServe's metric): a request counts iff
    # it finished AND met every SLO it carries (Request.slo_met) — shed
    # or late requests are misses.  Populations with no SLOs configured
    # reduce to plain request throughput / 100% attainment.
    slo_goodput_rps: float = float("nan")   # SLO-met requests per second
    request_slo_attainment: float = float("nan")  # met / ALL offered
    num_shed: int = 0               # dropped by the admission shed policy

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


class ServingEngine:
    def __init__(self, pool: ModelPool, target: str,
                 batch_size: int = 4, batch_wait_s: float = 0.25,
                 slo_latency_s: float = 30.0,
                 router_kwargs: Optional[dict] = None,
                 continuous: bool = True,
                 paged: Optional[bool] = None,
                 fused: Optional[bool] = None,
                 ttft_slo_s: Optional[float] = None,
                 tpot_slo_s: Optional[float] = None,
                 slo_aware: Optional[bool] = None,
                 shed_policy: str = "none",
                 mesh: Optional[object] = None):
        self.pool = pool
        self.target = target
        # --- mesh placement (``--mesh dxm``) ----------------------------
        # ``mesh`` is a "dxm" spec string ("2x4"), a jax Mesh, or a
        # prebuilt Placement.  The pool's members are placed BEFORE the
        # router exists (params/KV device_put under NamedSharding trees):
        # target tensor-parallel over the "model" axis, drafts replicated
        # (Placement.auto_assign) — pass a Placement with explicit
        # ``assign`` calls to override kinds.  None = trivial placement,
        # byte-identical to the unmeshed engine.
        if mesh is not None:
            placement = Placement.from_spec(mesh)
            if not placement.kinds:
                placement.auto_assign(pool.capability(), target)
            if pool.placement.is_trivial:
                pool.set_placement(placement)
            elif pool.placement.describe() != placement.describe():
                # a pool already serving on one mesh cannot be re-placed
                # under another (members hold device-put params); same
                # spec = reuse (several engines over one placed pool)
                raise ValueError(
                    f"pool is already placed on {pool.placement.describe()}"
                    f", cannot re-place on {placement.describe()}")
        self.batch_size = batch_size       # slot count in continuous mode
        self.batch_wait_s = batch_wait_s   # legacy batch-formation window
        self.slo = slo_latency_s
        self.continuous = continuous
        # --- SLO-aware serving (continuous mode) ------------------------
        # ``ttft_slo_s``/``tpot_slo_s`` fill in for requests that carry no
        # SLO of their own (per-request SLOs always win).  ``slo_aware``
        # switches the scheduler's objective to goodput (None = auto:
        # active iff any request carries an SLO); ``shed_policy="ttft"``
        # drops queued requests whose TTFT deadline is already unmeetable
        # instead of burning slot capacity on guaranteed misses.
        self.ttft_slo_s = ttft_slo_s
        self.tpot_slo_s = tpot_slo_s
        self.slo_aware = slo_aware
        if shed_policy not in ("none", "ttft"):
            raise ValueError(f"unknown shed_policy {shed_policy!r} "
                             "(expected 'none' or 'ttft')")
        self.shed_policy = shed_policy
        self.router_kwargs = dict(router_kwargs or {})
        if paged is not None:              # engine-level A/B convenience
            self.router_kwargs.setdefault("paged", paged)
        if fused is not None:              # device-resident cycles A/B
            self.router_kwargs.setdefault("fused", fused)
        self.router_kwargs.setdefault(
            "profiler", PerformanceProfiler(trace_cap=_SERVING_TRACE_CAP))
        # one router per engine: jit caches and scheduler state persist
        # across batches (recompiling per batch would bill compilation to
        # every request's latency)
        self._router = ChainRouter(self.pool, self.target,
                                   **self.router_kwargs)

    def run(self, requests: Sequence[Request]) -> ServingMetrics:
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        # engine-level SLO defaults fill requests that carry none
        if self.ttft_slo_s is not None or self.tpot_slo_s is not None:
            for r in reqs:
                if r.ttft_slo_s is None:
                    r.ttft_slo_s = self.ttft_slo_s
                if r.tpot_slo_s is None:
                    r.tpot_slo_s = self.tpot_slo_s
        has_slo = any(r.ttft_slo_s is not None or r.tpot_slo_s is not None
                      for r in reqs)
        # goodput objective: auto-activates when any request carries an
        # SLO; ``slo_aware=False`` forces the latency-only argmin even
        # then (the A/B baseline in benchmarks/goodput_ab.py)
        self._router.scheduler.slo_aware = (
            self.slo_aware if self.slo_aware is not None else has_slo)
        if self.continuous:
            acc_lens = self._run_continuous(reqs)
        else:
            acc_lens = self._run_legacy(reqs)
        return self._metrics(reqs, acc_lens)

    # ------------------------------------------------------------------
    # continuous mode: slot-level admission / retirement
    # ------------------------------------------------------------------
    def _run_continuous(self, reqs: List[Request]) -> List[float]:
        B = self.batch_size
        router = self._router
        lmax = max(len(r.prompt) + 2 * r.max_new_tokens + 2 for r in reqs)
        # max_block covers the widest per-cycle append: a linear window or
        # a whole token tree (tree mode appends all N nodes per cycle)
        margin = router.gcap + \
            (router.max_block + router.scheduler.max_chain_len) * 4
        # per-row sizing is only safe when EVERY pool member actually runs
        # the paged state: SSM/hybrid archs silently keep the contiguous
        # shared-pointer layout (ModelConfig.supports_paged), which still
        # burns cross-slot capacity under churn and needs the old headroom
        all_paged = router.paged and all(
            self.pool.cfg(m).supports_paged for m in self.pool.names())
        if all_paged:
            # block accounting: capacity is PER ROW — a slot only needs the
            # longest single request's own footprint (plus per-cycle
            # speculation margin); churn costs nothing because retirement
            # returns the row's blocks to the pool.  Tree shapes leave
            # masked dead-branch holes INSIDE a row (only trailing slots
            # are reclaimed; paged rows have no compaction path), so a
            # tree-configured router gets the hole-inclusive worst case:
            # a cycle commits >= 1 token but can strand up to the whole
            # N-node block, i.e. footprint <= prompt + budget·(N + gap).
            trees = router.tree_shapes + (
                (router.fixed_tree,) if router.fixed_tree is not None else ())
            if trees:
                n_max = max(t.num_nodes for t in trees)
                lmax = max(lmax,
                           max(len(r.prompt) + r.max_new_tokens * (n_max + 2)
                               for r in reqs))
            max_len = lmax + margin
        else:
            # contiguous shared-pointer state: double for cross-slot
            # fragmentation headroom (the router force-defrags and, as a
            # last resort, rebuilds states under capacity pressure)
            max_len = 2 * lmax + margin
        # pow-2 capacity buckets: session state shapes (and thus every
        # jitted program) are shared across workloads of similar size
        # instead of recompiling per run
        cap = 64
        while cap < max_len:
            cap *= 2
        sess = router.start_session(B, cap, session_id="serve")

        slot_req: List[Optional[Request]] = [None] * B
        clock = 0.0
        i = 0
        queue: List[Request] = []   # arrived, waiting for a free slot
        acc_lens: List[float] = []
        # each cycle commits >= 1 token per active slot, so total cycles is
        # bounded by the total token budget; the cap is a corruption guard
        cycle_cap = sum(r.max_new_tokens for r in reqs) * 4 + 16 * len(reqs)
        cycles = 0
        while (i < len(reqs) or queue
               or any(r is not None for r in slot_req)):
            busy = any(r is not None for r in slot_req)
            if not busy and not queue and reqs[i].arrival_s > clock:
                clock = reqs[i].arrival_s          # idle: jump to arrival
            # run-queue refill: every arrival up to the current clock
            while i < len(reqs) and reqs[i].arrival_s <= clock:
                queue.append(reqs[i])
                i += 1
            # shed policy: a queued request whose TTFT deadline is already
            # unmeetable — it cannot commit a first token before at least
            # one more cycle elapses (cycle-latency EMA) — is dropped NOW,
            # so slot capacity goes to requests that can still meet SLO
            if self.shed_policy == "ttft" and queue:
                est = self._router.profiler.cycle_time()
                kept = []
                for q in queue:
                    if clock + est >= q.ttft_deadline_s:
                        q.shed = True
                    else:
                        kept.append(q)
                queue = kept
            # SLO-aware admission order: earliest TTFT deadline first.
            # Requests without a TTFT SLO have an infinite deadline, and
            # the arrival-time tie-break keeps them (and whole no-SLO
            # populations) in exact FIFO order — today's behaviour.
            queue.sort(key=lambda q: (q.ttft_deadline_s, q.arrival_s))
            for s in range(B):
                if slot_req[s] is None and queue:
                    r = queue.pop(0)
                    r.start_s = clock   # queueing ends, service begins
                    clock += sess.admit(s, r.prompt, r.max_new_tokens,
                                        ttft_slo_s=r.ttft_slo_s,
                                        tpot_slo_s=r.tpot_slo_s)
                    slot_req[s] = r
            # publish the load signal the goodput-aware chain search
            # reads: residual run-queue depth, slot occupancy, and the
            # profiler's cycle-latency EMA
            busy_n = sum(r is not None for r in slot_req)
            self._router.scheduler.set_load(LoadSignal(
                queue_depth=len(queue), occupancy=busy_n / B,
                cycle_ema_s=self._router.profiler.cycle_time(),
                num_slots=B))
            rep = sess.run_cycle()
            clock += rep.wall_s
            cycles += 1
            if rep.commits.any():
                acc_lens.append(rep.acc_mean)
            for s in range(B):
                r = slot_req[s]
                if r is None:
                    continue
                if rep.commits[s] > 0 and r.first_token_s < 0:
                    r.first_token_s = clock
                if not sess.active[s]:
                    r.finish_s = clock
                    r.output_tokens = sess.retire(s)
                    r.generated = len(r.output_tokens)
                    slot_req[s] = None
            if cycles > cycle_cap:
                raise RuntimeError("continuous engine exceeded cycle cap "
                                   "(stuck slot?)")
        sess.close()
        # the load signal is scoped to this run — a later run (or a bare
        # scheduler user) must not inherit a stale pressure reading
        self._router.scheduler.set_load(None)
        return acc_lens

    # ------------------------------------------------------------------
    # legacy mode: stop-the-world batch formation (A/B baseline)
    # ------------------------------------------------------------------
    def _run_legacy(self, reqs: List[Request]) -> List[float]:
        clock = 0.0
        i = 0
        batch_no = 0
        acc_lens: List[float] = []
        while i < len(reqs):
            batch = [reqs[i]]
            i += 1
            # batch formation: wait for up to batch_size or batch_wait_s
            window_end = max(clock, batch[0].arrival_s) + self.batch_wait_s
            while (i < len(reqs) and len(batch) < self.batch_size
                   and reqs[i].arrival_s <= window_end):
                batch.append(reqs[i])
                i += 1
            start = max(clock, max(r.arrival_s for r in batch))
            acc = self._serve_batch(batch, start, f"batch{batch_no}")
            batch_no += 1
            acc_lens.extend(acc)
            clock = max(r.finish_s for r in batch)
        return acc_lens

    def _serve_batch(self, batch: List[Request], start: float,
                     batch_key: str) -> List[float]:
        B = len(batch)
        maxlen = max(len(r.prompt) for r in batch)
        prompt = np.zeros((B, maxlen), np.int64)
        lens = np.zeros(B, np.int64)
        for b, r in enumerate(batch):
            prompt[b, :len(r.prompt)] = r.prompt
            lens[b] = len(r.prompt)
            r.start_s = start
        budgets = np.array([r.max_new_tokens for r in batch])

        # state keys are namespaced by the batch, not by any single
        # request's id: each slot row of the batch state is distinct and
        # two batches can never collide on a shared request id
        res = self._router.generate(prompt, lens, max_new_tokens=budgets,
                                    request_id=batch_key)

        # reconstruct per-request timing from per-cycle commits
        t = start + res.prefill_wall_s
        cum = np.zeros(B, np.int64)
        first_at = np.full(B, -1.0)
        done_at = np.full(B, -1.0)
        gen_len = np.array([len(g) for g in res.generated])
        for wall, commits in zip(res.cycle_wall_s, res.commits_per_cycle):
            t += wall
            newly = (cum == 0) & (commits > 0)
            first_at[newly] = t
            cum += commits
            fin = (done_at < 0) & (cum >= np.minimum(budgets, gen_len))
            done_at[fin] = t
        done_at[done_at < 0] = t
        first_at[first_at < 0] = t
        for b, r in enumerate(batch):
            r.first_token_s = first_at[b]
            r.finish_s = done_at[b]
            r.generated = int(gen_len[b])
            r.output_tokens = res.generated[b]
        return res.acceptance_lengths

    # ------------------------------------------------------------------
    def _metrics(self, reqs: List[Request],
                 acc_lens: List[float]) -> ServingMetrics:
        done = [r for r in reqs if r.finish_s >= 0]
        num_shed = sum(1 for r in reqs if r.shed)
        # per-request SLO attainment over the WHOLE offered population:
        # shed and unfinished requests are misses by definition
        attain = (float(np.mean([r.slo_met for r in reqs])) if reqs
                  else float("nan"))
        if not done:
            # degenerate run (nothing finished): NaN-safe metrics instead
            # of max()/mean() raising on empty sequences
            nan = float("nan")
            return ServingMetrics(
                goodput_tps=nan, request_throughput_rps=nan,
                avg_ttft_s=nan, p95_ttft_s=nan, avg_tpot_s=nan,
                avg_latency_s=nan, p95_latency_s=nan, slo_attainment=nan,
                total_tokens=0, num_requests=0, makespan_s=0.0,
                avg_acceptance_len=0.0, avg_queue_s=0.0,
                slo_goodput_rps=nan, request_slo_attainment=attain,
                num_shed=num_shed)
        total_tokens = sum(r.generated for r in done)
        makespan = max(r.finish_s for r in done) - min(r.arrival_s
                                                       for r in done)
        ttfts = np.array([r.ttft for r in done])
        lats = np.array([r.latency for r in done])
        tpots = np.array([r.tpot for r in done if np.isfinite(r.tpot)])
        queues = np.array([r.queue_delay for r in done
                           if np.isfinite(r.queue_delay)])
        # a single instant request gives makespan == 0 — rates are
        # undefined there, not infinite
        rate_denom = makespan if makespan > 0 else float("nan")
        return ServingMetrics(
            goodput_tps=total_tokens / rate_denom,
            request_throughput_rps=len(done) / rate_denom,
            avg_ttft_s=float(ttfts.mean()),
            p95_ttft_s=float(np.percentile(ttfts, 95)),
            avg_tpot_s=float(tpots.mean()) if tpots.size else float("nan"),
            avg_latency_s=float(lats.mean()),
            p95_latency_s=float(np.percentile(lats, 95)),
            slo_attainment=float(np.mean(lats <= self.slo)),
            total_tokens=total_tokens,
            num_requests=len(done),
            makespan_s=makespan,
            avg_acceptance_len=float(np.mean(acc_lens)) if acc_lens else 0.0,
            avg_queue_s=float(queues.mean()) if queues.size else 0.0,
            slo_goodput_rps=sum(r.slo_met for r in done) / rate_denom,
            request_slo_attainment=attain,
            num_shed=num_shed,
        )
