from .engine import ServingEngine, ServingMetrics
