"""Train the demo Llama-family pool on the synthetic corpus and cache the
weights — the substrate for every serving benchmark/example (paper §5:
same-tokenizer model family with a real capability gradient)."""
from __future__ import annotations

import os
import time
from typing import Tuple

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt
from ..configs.llama_pool import demo_pool
from ..core import ModelPool
from ..data import CorpusConfig, SyntheticCorpus
from ..models.model import LanguageModel
from .step import init_train_state, make_train_step

DEFAULT_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../checkpoints/demo_pool")


def train_one(cfg, corpus: SyntheticCorpus, steps: int, batch: int = 16,
              seq: int = 96, lr: float = 1e-3, log_every: int = 100,
              seed: int = 0, verbose: bool = True):
    lm = LanguageModel(cfg)
    ts = init_train_state(lm, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(lm, base_lr=lr, warmup=20,
                                      total=steps, remat=False))
    it = corpus.batches(batch, seq, seed=seed + 1)
    losses = []
    t0 = time.perf_counter()
    for s in range(steps):
        tokens = jnp.asarray(next(it))
        ts, metrics = step_fn(ts, tokens)
        if s % log_every == 0 or s == steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            if verbose:
                print(f"  [{cfg.name}] step {s:4d} loss {loss:.4f} "
                      f"({time.perf_counter()-t0:.0f}s)")
    return ts.params, losses


def build_trained_pool(steps: int = 400, ckpt_dir: str = DEFAULT_DIR,
                       vocab_size: int = 512, force: bool = False,
                       verbose: bool = True
                       ) -> Tuple[ModelPool, SyntheticCorpus]:
    """Returns (ModelPool with trained demo models, corpus). Cached on disk."""
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=vocab_size))
    pool = ModelPool()
    for i, cfg in enumerate(demo_pool(vocab_size)):
        lm = LanguageModel(cfg)
        path = os.path.join(ckpt_dir, cfg.name)
        params0, axes = lm.init(jax.random.PRNGKey(42 + i))
        loaded = False
        if ckpt.exists(path) and not force:
            try:
                params = jax.tree.map(jnp.asarray, ckpt.load(path, params0))
                loaded = True
                if verbose:
                    print(f"[pool] loaded {cfg.name} from {path}")
            except AssertionError:
                if verbose:
                    print(f"[pool] stale checkpoint for {cfg.name}; "
                          "retraining")
        if not loaded:
            if verbose:
                print(f"[pool] training {cfg.name} ({steps} steps)…")
            params, _ = train_one(cfg, corpus, steps, seed=7 * i,
                                  verbose=verbose)
            ckpt.save(path, params, metadata={"steps": steps,
                                              "vocab": vocab_size})
        pool.register(cfg, params=params, param_axes=axes)
    return pool, corpus
