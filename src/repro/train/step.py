"""Training step: causal-LM loss (+ MoE aux), AdamW update, remat policy.

The same function is used by the CPU examples (tiny pool training) and by
the multi-pod dry-run (train_4k lowering)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.model import LanguageModel
from ..optim import AdamWState, adamw_init, adamw_update, cosine_schedule


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState


def loss_fn(lm: LanguageModel, params, tokens, loss_mask=None,
            remat: bool = True, extras: Optional[Dict] = None):
    """Next-token CE over tokens; `loss_mask` (B, S) optionally masks pads.

    Returns (loss, metrics)."""
    extras = extras or {}
    out = lm.train_logits(params, tokens, remat=remat, **extras)
    logits, aux = out if lm.has_aux_loss() else (out, jnp.zeros((), jnp.float32))
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    if loss_mask is not None:
        m = loss_mask[:, 1:].astype(jnp.float32)
        ce = -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        ce = -jnp.mean(ll)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(lm: LanguageModel, base_lr: float = 3e-4,
                    warmup: int = 20, total: int = 1000,
                    remat: bool = True):
    def step(ts: TrainState, tokens, loss_mask=None, extras=None):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(lm, p, tokens, loss_mask, remat, extras),
            has_aux=True)(ts.params)
        lr = cosine_schedule(ts.opt.step, base_lr, warmup, total)
        new_params, new_opt = adamw_update(ts.params, grads, ts.opt, lr)
        return TrainState(new_params, new_opt), {**metrics, "loss": loss,
                                                 "lr": lr}
    return step


def init_train_state(lm: LanguageModel, key) -> TrainState:
    params, _ = lm.init(key)
    return TrainState(params=params, opt=adamw_init(params))
