"""Request workload generation (paper §5 Workloads): open-loop arrivals
with input/output length profiles modeled on the four evaluation datasets.

Three arrival processes:

  * ``make_workload`` — homogeneous Poisson (the paper's §5 setup);
  * ``make_bursty_workload`` — a two-state Markov-modulated Poisson
    process (MMPP): exponentially-distributed ON/OFF dwell times with a
    different arrival rate in each state.  This is the bursty regime that
    motivates SLO-aware scheduling (SpecServe/AdaSpec, arXiv:2503.05096):
    an engine sized for the average rate is transiently oversubscribed
    during every ON burst;
  * ``load_trace`` / ``save_trace`` — JSONL arrival-trace replay, so a
    recorded (or hand-built) arrival pattern is exactly reproducible
    across A/B arms and CI runs.

Length profiles are lognormal approximations of the public datasets'
prompt/answer statistics (GSM8K: short math prompts / medium answers;
HumanEval: medium code prompts / medium-long answers; MTBench: long
multi-turn contexts / long answers; MGSM: GSM8K-like, multilingual).

Every request can carry a TTFT/TPOT SLO (``ttft_slo_s``/``tpot_slo_s``):
per-dataset defaults (``DATASET_SLOS``) apply when a generator is asked
for SLOs, and explicit values override them.  Requests without SLOs are
scheduled exactly as before — the SLO-aware serving path degenerates to
the latency-only scheduler (pinned by ``tests/test_slo_scheduling.py``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np

# (prompt_mu, prompt_sigma, out_mu, out_sigma) in log-token space
DATASET_PROFILES = {
    "gsm8k":     (np.log(55),  0.4, np.log(120), 0.5),
    "humaneval": (np.log(130), 0.5, np.log(160), 0.6),
    "mtbench":   (np.log(210), 0.6, np.log(200), 0.6),
    "mgsm":      (np.log(65),  0.4, np.log(130), 0.5),
}

# default (ttft_slo_s, tpot_slo_s) per dataset: interactive budgets scaled
# to the CPU-host demo (short math turns are latency-sensitive, long
# multi-turn chat tolerates a slower first token)
DATASET_SLOS = {
    "gsm8k":     (2.0, 0.5),
    "humaneval": (4.0, 0.6),
    "mtbench":   (6.0, 0.8),
    "mgsm":      (2.0, 0.5),
}


@dataclasses.dataclass
class Request:
    request_id: str
    arrival_s: float
    prompt: np.ndarray          # (Lp,) int64
    max_new_tokens: int
    dataset: str
    # service-level objectives (None = no SLO on that axis):
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None
    # filled by the engine:
    start_s: float = -1.0        # slot admission (continuous) / batch start
    first_token_s: float = -1.0
    finish_s: float = -1.0
    generated: int = 0
    shed: bool = False           # dropped by the admission shed policy
    output_tokens: Optional[np.ndarray] = None   # committed stream (A/B
                                 # bit-equality vs target-only decode)

    @property
    def ttft(self):
        """Time to first token, queueing delay included: the clock starts
        at arrival, not at admission."""
        return self.first_token_s - self.arrival_s

    @property
    def queue_delay(self):
        """Arrival -> slot-admission (or batch-start) wait."""
        if self.start_s < 0:
            return float("nan")
        return self.start_s - self.arrival_s

    @property
    def latency(self):
        return self.finish_s - self.arrival_s

    @property
    def tpot(self):
        if self.generated <= 1:
            return float("nan")
        return (self.finish_s - self.first_token_s) / (self.generated - 1)

    @property
    def ttft_deadline_s(self) -> float:
        """Absolute wall deadline for the first token (inf = no TTFT SLO).
        Earliest-deadline-first admission orders the run queue by this."""
        if self.ttft_slo_s is None:
            return float("inf")
        return self.arrival_s + self.ttft_slo_s

    @property
    def slo_met(self) -> bool:
        """Did the request meet every SLO it carries?  Shed or unfinished
        requests are misses; a finished request with no SLO counts as met
        (goodput over a no-SLO population equals plain throughput)."""
        if self.shed or self.finish_s < 0:
            return False
        if self.ttft_slo_s is not None and self.ttft > self.ttft_slo_s:
            return False
        if self.tpot_slo_s is not None:
            t = self.tpot
            if np.isfinite(t) and t > self.tpot_slo_s:
                return False
        return True


def resolve_slo(dataset: str, ttft_slo: Optional[float] = None,
                tpot_slo: Optional[float] = None,
                with_slo: bool = False
                ) -> Tuple[Optional[float], Optional[float]]:
    """SLO resolution used by every generator: explicit values win; with
    ``with_slo`` the dataset defaults fill whichever axis is unset; with
    neither, the request carries no SLO at all."""
    if not with_slo and ttft_slo is None and tpot_slo is None:
        return None, None
    d_ttft, d_tpot = DATASET_SLOS.get(dataset, (None, None))
    return (ttft_slo if ttft_slo is not None else (d_ttft if with_slo
                                                   else None),
            tpot_slo if tpot_slo is not None else (d_tpot if with_slo
                                                   else None))


def _sample_request(corpus, dataset: str, rng, i: int, t: float,
                    scale: float, max_prompt: int, max_out: int,
                    ttft_slo: Optional[float],
                    tpot_slo: Optional[float]) -> Request:
    pmu, psig, omu, osig = DATASET_PROFILES[dataset]
    Lp = int(np.clip(rng.lognormal(pmu, psig) * scale, 4, max_prompt))
    Lo = int(np.clip(rng.lognormal(omu, osig) * scale, 4, max_out))
    return Request(request_id=f"{dataset}-{i}", arrival_s=t,
                   prompt=corpus.sample(rng, Lp), max_new_tokens=Lo,
                   dataset=dataset, ttft_slo_s=ttft_slo,
                   tpot_slo_s=tpot_slo)


def make_workload(corpus, dataset: str, rate_rps: float, duration_s: float,
                  seed: int = 0, scale: float = 0.25,
                  max_prompt: int = 96, max_out: int = 48,
                  with_slo: bool = False,
                  ttft_slo: Optional[float] = None,
                  tpot_slo: Optional[float] = None) -> List[Request]:
    """Poisson arrivals; lengths drawn from the dataset profile, scaled down
    by ``scale`` so the CPU-host demo stays tractable while preserving the
    relative dataset shapes."""
    ttft_slo, tpot_slo = resolve_slo(dataset, ttft_slo, tpot_slo, with_slo)
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Request] = []
    i = 0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        if t >= duration_s:
            break
        out.append(_sample_request(corpus, dataset, rng, i, t, scale,
                                   max_prompt, max_out, ttft_slo, tpot_slo))
        i += 1
    return out


def make_bursty_workload(corpus, dataset: str, rate_on_rps: float,
                         duration_s: float, rate_off_rps: float = 0.0,
                         mean_on_s: float = 2.0, mean_off_s: float = 6.0,
                         seed: int = 0, scale: float = 0.25,
                         max_prompt: int = 96, max_out: int = 48,
                         start_on: bool = True,
                         with_slo: bool = False,
                         ttft_slo: Optional[float] = None,
                         tpot_slo: Optional[float] = None,
                         return_states: bool = False):
    """Two-state MMPP arrivals: exponential ON/OFF dwell times
    (``mean_on_s``/``mean_off_s``) with Poisson arrivals at
    ``rate_on_rps`` during ON and ``rate_off_rps`` during OFF.  The
    long-run arrival-rate duty cycle is

        rate_on·mean_on / (rate_on·mean_on + rate_off·mean_off)

    so ``rate_off_rps=0`` concentrates ALL arrivals inside the bursts —
    the oversubscription regime SLO-aware scheduling targets.

    ``return_states=True`` additionally returns the simulated state
    intervals ``[(start_s, end_s, is_on), ...]`` (conformance tests pin
    the duty cycle and the arrivals-inside-bursts invariant against
    them)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    on = bool(start_on)
    state_start = 0.0
    state_end = rng.exponential(mean_on_s if on else mean_off_s)
    intervals: List[Tuple[float, float, bool]] = []
    ttft_slo, tpot_slo = resolve_slo(dataset, ttft_slo, tpot_slo, with_slo)
    out: List[Request] = []
    i = 0
    while t < duration_s:
        rate = rate_on_rps if on else rate_off_rps
        dt = rng.exponential(1.0 / rate) if rate > 0 else float("inf")
        if t + dt <= state_end:
            t += dt
            if t >= duration_s:
                break
            out.append(_sample_request(corpus, dataset, rng, i, t, scale,
                                       max_prompt, max_out, ttft_slo,
                                       tpot_slo))
            i += 1
        else:
            # no arrival before the switch: jump to the boundary (the
            # exponential is memoryless, so discarding the partial draw
            # keeps the process exact) and flip states
            intervals.append((state_start, min(state_end, duration_s), on))
            t = state_end
            on = not on
            state_start = t
            state_end = t + rng.exponential(mean_on_s if on else mean_off_s)
    if state_start < duration_s:
        intervals.append((state_start, duration_s, on))
    if return_states:
        return out, intervals
    return out


# ---------------------------------------------------------------------------
# JSONL arrival-trace replay
# ---------------------------------------------------------------------------
def save_trace(requests: Sequence[Request], path: str) -> None:
    """Write an arrival trace (one JSON object per line) capturing the
    open-loop inputs of each request — arrival time, prompt tokens,
    generation budget, dataset tag, SLOs.  Engine-filled timing fields
    are deliberately NOT saved: a trace replays arrivals, not outcomes."""
    with open(path, "w") as f:
        for r in requests:
            f.write(json.dumps({
                "request_id": r.request_id,
                "arrival_s": float(r.arrival_s),
                "prompt": np.asarray(r.prompt).astype(int).tolist(),
                "max_new_tokens": int(r.max_new_tokens),
                "dataset": r.dataset,
                "ttft_slo_s": r.ttft_slo_s,
                "tpot_slo_s": r.tpot_slo_s,
            }) + "\n")


def load_trace(path: str, ttft_slo: Optional[float] = None,
               tpot_slo: Optional[float] = None) -> List[Request]:
    """Load a JSONL arrival trace written by ``save_trace`` (or by hand).
    ``ttft_slo``/``tpot_slo`` override the per-request SLOs when given
    (replaying one trace under several SLO regimes)."""
    out: List[Request] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            out.append(Request(
                request_id=d["request_id"],
                arrival_s=float(d["arrival_s"]),
                prompt=np.asarray(d["prompt"], np.int64),
                max_new_tokens=int(d["max_new_tokens"]),
                dataset=d.get("dataset", "trace"),
                ttft_slo_s=(ttft_slo if ttft_slo is not None
                            else d.get("ttft_slo_s")),
                tpot_slo_s=(tpot_slo if tpot_slo is not None
                            else d.get("tpot_slo_s"))))
    out.sort(key=lambda r: r.arrival_s)
    return out


def streams_bit_exact(requests: Sequence[Request],
                      references: Sequence[np.ndarray]) -> bool:
    """A/B bit-equality helper: every SERVED request's committed stream
    must equal its reference (target-only) stream.  Shed requests have no
    stream and are skipped.  A served request with ``output_tokens``
    unset raises a clear ValueError instead of the silent
    False/TypeError ``np.array_equal(None, ...)`` produces."""
    if len(requests) != len(references):
        raise ValueError(
            f"bit-equality check over mismatched populations: "
            f"{len(requests)} requests vs {len(references)} references")
    for r, ref in zip(requests, references):
        if r.shed:
            continue
        if r.output_tokens is None:
            raise ValueError(
                f"request {r.request_id!r} has no committed output stream "
                "(output_tokens unset) — run it through an engine before "
                "bit-equality checks")
        if not np.array_equal(np.asarray(r.output_tokens),
                              np.asarray(ref)):
            return False
    return True
