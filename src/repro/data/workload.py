"""Request workload generation (paper §5 Workloads): Poisson arrivals with
input/output length profiles modeled on the four evaluation datasets.

Length profiles are lognormal approximations of the public datasets'
prompt/answer statistics (GSM8K: short math prompts / medium answers;
HumanEval: medium code prompts / medium-long answers; MTBench: long
multi-turn contexts / long answers; MGSM: GSM8K-like, multilingual)."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

# (prompt_mu, prompt_sigma, out_mu, out_sigma) in log-token space
DATASET_PROFILES = {
    "gsm8k":     (np.log(55),  0.4, np.log(120), 0.5),
    "humaneval": (np.log(130), 0.5, np.log(160), 0.6),
    "mtbench":   (np.log(210), 0.6, np.log(200), 0.6),
    "mgsm":      (np.log(65),  0.4, np.log(130), 0.5),
}


@dataclasses.dataclass
class Request:
    request_id: str
    arrival_s: float
    prompt: np.ndarray          # (Lp,) int64
    max_new_tokens: int
    dataset: str
    # filled by the engine:
    start_s: float = -1.0        # slot admission (continuous) / batch start
    first_token_s: float = -1.0
    finish_s: float = -1.0
    generated: int = 0
    output_tokens: np.ndarray = None   # committed stream (A/B bit-equality
                                       # checks against target-only decode)

    @property
    def ttft(self):
        """Time to first token, queueing delay included: the clock starts
        at arrival, not at admission."""
        return self.first_token_s - self.arrival_s

    @property
    def queue_delay(self):
        """Arrival -> slot-admission (or batch-start) wait."""
        if self.start_s < 0:
            return float("nan")
        return self.start_s - self.arrival_s

    @property
    def latency(self):
        return self.finish_s - self.arrival_s

    @property
    def tpot(self):
        if self.generated <= 1:
            return float("nan")
        return (self.finish_s - self.first_token_s) / (self.generated - 1)


def make_workload(corpus, dataset: str, rate_rps: float, duration_s: float,
                  seed: int = 0, scale: float = 0.25,
                  max_prompt: int = 96, max_out: int = 48) -> List[Request]:
    """Poisson arrivals; lengths drawn from the dataset profile, scaled down
    by ``scale`` so the CPU-host demo stays tractable while preserving the
    relative dataset shapes."""
    pmu, psig, omu, osig = DATASET_PROFILES[dataset]
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Request] = []
    i = 0
    while t < duration_s:
        t += rng.exponential(1.0 / rate_rps)
        Lp = int(np.clip(rng.lognormal(pmu, psig) * scale, 4, max_prompt))
        Lo = int(np.clip(rng.lognormal(omu, osig) * scale, 4, max_out))
        out.append(Request(
            request_id=f"{dataset}-{i}", arrival_s=t,
            prompt=corpus.sample(rng, Lp), max_new_tokens=Lo,
            dataset=dataset))
        i += 1
    return out
