from .synthetic import CorpusConfig, SyntheticCorpus
from .workload import (DATASET_PROFILES, DATASET_SLOS, Request, load_trace,
                       make_bursty_workload, make_workload, resolve_slo,
                       save_trace, streams_bit_exact)
