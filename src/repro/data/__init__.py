from .synthetic import CorpusConfig, SyntheticCorpus
from .workload import DATASET_PROFILES, Request, make_workload
