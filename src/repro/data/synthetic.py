"""Synthetic LM corpus with learnable structure.

A seeded low-entropy Markov chain over the vocab plus deterministic motif
insertions.  Models of different capacity learn it to different degrees, so
a trained tiny pool exhibits the capability gradient (and the inter-model
distributional similarity) that the paper's Llama pool has — random-init
models would have ~0 acceptance and make speculation trivially useless.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class CorpusConfig:
    vocab_size: int = 512
    branching: int = 6          # out-degree of the Markov chain
    motif_len: int = 8
    num_motifs: int = 24
    motif_prob: float = 0.25
    seed: int = 1234


class SyntheticCorpus:
    def __init__(self, cfg: CorpusConfig = CorpusConfig()):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # sparse Markov transitions: each token has `branching` successors
        self.succ = rng.integers(0, V, size=(V, cfg.branching))
        # skewed successor distribution (zipf-ish)
        w = 1.0 / np.arange(1, cfg.branching + 1)
        self.succ_p = w / w.sum()
        self.motifs = rng.integers(0, V, size=(cfg.num_motifs, cfg.motif_len))

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        V = self.cfg.vocab_size
        out = np.empty(length, np.int64)
        t = int(rng.integers(0, V))
        i = 0
        while i < length:
            if rng.random() < self.cfg.motif_prob:
                m = self.motifs[rng.integers(0, self.cfg.num_motifs)]
                n = min(len(m), length - i)
                out[i:i + n] = m[:n]
                i += n
                t = int(out[i - 1])
            else:
                t = int(rng.choice(self.succ[t], p=self.succ_p))
                out[i] = t
                i += 1
        return out

    def batches(self, batch: int, seq: int, seed: int = 0
                ) -> Iterator[np.ndarray]:
        rng = np.random.default_rng(seed)
        while True:
            yield np.stack([self.sample(rng, seq) for _ in range(batch)])

    def prompts(self, n: int, min_len: int, max_len: int, seed: int = 0
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded prompt batch (n, max_len) + lengths."""
        rng = np.random.default_rng(seed)
        lens = rng.integers(min_len, max_len + 1, size=n)
        toks = np.zeros((n, max_len), np.int64)
        for i, L in enumerate(lens):
            toks[i, :L] = self.sample(rng, int(L))
        return toks, lens
