"""Quickstart: build a 3-model pool, generate with adaptive multi-level
speculative decoding, and verify the paper's output-quality guarantee.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChainRouter, ModelPool
from repro.models import ModelConfig
from repro.models.model import LanguageModel


def main():
    # 1. a heterogeneous pool (random weights — quickstart only; see
    #    serve_specrouter.py for the trained pool with real acceptance)
    pool = ModelPool()
    for (name, layers, d, seed) in [("draft-s", 2, 32, 1),
                                    ("mid-m", 3, 48, 2),
                                    ("target-l", 4, 64, 3)]:
        cfg = ModelConfig(name=name, arch_type="dense", num_layers=layers,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=97, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(seed))
        pool.register(cfg, params=params, param_axes=axes)

    prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                         (2, 8), 0, 97))
    plens = np.array([8, 6])

    # 2. adaptive SpecRouter generation
    router = ChainRouter(pool, target="target-l", greedy=True, adaptive=True)
    out = router.generate(prompt, plens, max_new_tokens=16, request_id="q")
    print("generated:", [g.tolist() for g in out.generated])
    hist = {}
    for c, w in out.chain_history:
        hist[(c, w)] = hist.get((c, w), 0) + 1
    print("chains used:", {f"{'->'.join(c)} (W={w})": n
                           for (c, w), n in hist.items()})
    print("mean acceptance length:", round(float(np.mean(
        out.acceptance_lengths)), 2))

    # 3. output-quality guarantee: identical to target-only greedy
    ref = ChainRouter(pool, "target-l", greedy=True, adaptive=False,
                      fixed_chain=("target-l",), fixed_window=1
                      ).generate(prompt, plens, 16, request_id="r")
    for b in range(2):
        assert np.array_equal(out.generated[b], ref.generated[b])
    print("output == target-only greedy ✓ (paper §5 Output Quality)")


if __name__ == "__main__":
    main()
