"""End-to-end training driver: trains the demo Llama-family pool (the
paper's §5 model family, CPU-scaled) on the synthetic corpus for a few
hundred AdamW steps each, with loss curves and checkpointing.

    PYTHONPATH=src python examples/train_pool.py [--steps 400] [--force]
"""
import argparse

from repro.train.pool import build_trained_pool


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--force", action="store_true",
                    help="retrain even if checkpoints exist")
    args = ap.parse_args()
    pool, corpus = build_trained_pool(steps=args.steps, force=args.force)
    print("pool ready:", pool.names())
    print("capabilities (param counts):",
          {k: f"{v:.2e}" for k, v in pool.capability().items()})


if __name__ == "__main__":
    main()
