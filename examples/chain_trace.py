"""Figure-2 style demo: watch the ModelChainScheduler's predicted T_eff
table and its chain/window selection evolve during one generation.

    PYTHONPATH=src python examples/chain_trace.py
"""
import numpy as np

from repro.core import ChainRouter
from repro.train.pool import build_trained_pool


def main():
    pool, corpus = build_trained_pool()
    prompts, lens = corpus.prompts(2, 12, 20, seed=11)
    router = ChainRouter(pool, "demo-7b", greedy=True, adaptive=True,
                         reschedule_every=1)
    out = router.generate(prompts, lens, 24, request_id="trace")

    print("similarity table (SimScore = 1 - E[DTV], Eq. 6):")
    for (a, b), s in sorted(router.sims.table().items()):
        print(f"  {a:>9} ~ {b:<9}: {s:.3f}")
    print("\nprofiled per-token times (EMA):")
    for m in pool.names():
        print(f"  {m:>9}: {router.profiler.decode_time(m, 0)*1e3:.2f} ms")

    choice = router.scheduler.get_optimal_chain()
    print("\npredicted T_eff per candidate (chain, shape) [ms/token]:")
    for (chain, w, tr), t in sorted(choice.table.items(),
                                    key=lambda kv: kv[1]):
        sel = (chain, w, tr) == (choice.chain, choice.window, choice.tree)
        shape = f"tree={tr}" if tr is not None else f"W={w}"
        tag = "  <== selected" if sel else ""
        print(f"  {'->'.join(chain):<28} {shape}: {t*1e3:8.2f}{tag}")

    hist = {}
    for c, w in out.chain_history:
        hist[(c, w)] = hist.get((c, w), 0) + 1
    print("\nchains actually used over", out.steps, "cycles:")
    for (c, w), n in sorted(hist.items(), key=lambda kv: -kv[1]):
        print(f"  {'->'.join(c):<28} W={w}: {n} cycles")
    print("mean acceptance:", round(float(np.mean(out.acceptance_lengths)),
                                    2))


if __name__ == "__main__":
    main()
