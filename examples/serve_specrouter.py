"""End-to-end serving driver (the paper's kind of system): Poisson request
arrivals from a dataset profile, slot-level continuously-batched
multi-level speculative serving, full §5 metric report, with TMO / SSD
baselines for the EAF speedup.

    PYTHONPATH=src python examples/serve_specrouter.py \
        [--dataset gsm8k] [--rate 0.5] [--duration 20] [--batch 4] \
        [--tree 2x2x1]      # token-tree speculation (SSD-Tree baseline +
                            # the shape joins SpecRouter's search space)
        [--no-continuous]   # legacy stop-the-world batch formation
        [--no-paged]        # legacy contiguous shared-pointer KV (A/B)
        [--no-slot-routing] # legacy global-chain routing: one chain per
                            # cycle, whole pool prefilled at admission
        [--no-fused]        # legacy host-orchestrated per-op cycles (A/B)
        [--profile-every N] # unfused profiling-cycle cadence (default 16)
        [--workload burst]  # MMPP bursty arrivals instead of Poisson
        [--workload trace --trace-file t.jsonl]  # JSONL trace replay
        [--ttft-slo 2.0] [--tpot-slo 0.5]  # per-request SLOs: activates
                            # the goodput-aware chain search + EDF
                            # admission (per-dataset defaults via the
                            # workload's with_slo are in data/workload.py)
        [--shed]            # drop queued requests whose TTFT deadline is
                            # already unmeetable (goodput over latency)
        [--mesh dxm]        # mesh-sharded serving: place the pool on a
                            # ("data","model") device mesh (target
                            # tensor-parallel, drafts replicated); on a
                            # CPU host virtual devices are spawned
                            # automatically to fill the mesh
"""
import argparse
import math
import os
import sys

# --mesh needs the devices to EXIST before jax initializes its backend:
# spawn virtual CPU devices (the launch/dryrun.py recipe) before any
# jax-importing import below runs.  Respect a user-provided XLA_FLAGS.
if "--mesh" in sys.argv and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    _spec = sys.argv[sys.argv.index("--mesh") + 1]
    _n = 1
    for _p in _spec.split("x"):
        _n *= int(_p)
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_n}"
                               ).strip()

import numpy as np

from repro.data import load_trace, make_bursty_workload, make_workload
from repro.serving import ServingEngine
from repro.train.pool import build_trained_pool


def build_requests(corpus, args):
    slo = dict(ttft_slo=args.ttft_slo, tpot_slo=args.tpot_slo)
    if args.workload == "trace":
        return load_trace(args.trace_file, **slo)
    if args.workload == "burst":
        # ON bursts at 4x the nominal rate, 25% duty cycle -> same
        # offered load as the Poisson arm but arriving in clumps
        return make_bursty_workload(
            corpus, args.dataset, rate_on_rps=4.0 * args.rate,
            duration_s=args.duration, mean_on_s=2.0, mean_off_s=6.0,
            seed=7, **slo)
    return make_workload(corpus, args.dataset, args.rate, args.duration,
                         seed=7, **slo)


def run(pool, corpus, args, label, router_kwargs):
    router_kwargs = dict(router_kwargs, paged=not args.no_paged,
                         slot_routing=not args.no_slot_routing,
                         fused=not args.no_fused,
                         profile_every=args.profile_every)
    reqs = build_requests(corpus, args)
    eng = ServingEngine(pool, "demo-7b", batch_size=args.batch,
                        slo_latency_s=args.slo,
                        shed_policy="ttft" if args.shed else "none",
                        router_kwargs=router_kwargs,
                        continuous=not args.no_continuous,
                        mesh=args.mesh)
    m = eng.run(reqs)
    line = (f"[{label:<22}] goodput {m.goodput_tps:7.1f} tok/s | "
            f"TTFT {m.avg_ttft_s:6.2f}s (p95 {m.p95_ttft_s:5.2f}s, "
            f"queue {m.avg_queue_s:5.2f}s) | TPOT {m.avg_tpot_s*1e3:7.1f}ms | "
            f"p95 lat {m.p95_latency_s:6.2f}s | SLO {m.slo_attainment:5.1%} | "
            f"acc-len {m.avg_acceptance_len:4.2f}")
    if not math.isnan(m.request_slo_attainment):
        line += (f" | SLO-req {m.request_slo_attainment:5.1%} "
                 f"(shed {m.num_shed})")
    print(line)
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="gsm8k",
                    choices=["gsm8k", "humaneval", "mtbench", "mgsm"])
    ap.add_argument("--rate", type=float, default=0.4)
    ap.add_argument("--duration", type=float, default=25.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slo", type=float, default=60.0)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--tree", default=None, metavar="SHAPE",
                    help="token-tree speculation shape, e.g. 2x2x1: adds "
                         "an SSD-Tree static baseline and lets the "
                         "adaptive scheduler pick the tree draft")
    ap.add_argument("--no-continuous", action="store_true",
                    help="legacy stop-the-world batch formation (A/B)")
    ap.add_argument("--no-paged", action="store_true",
                    help="legacy contiguous shared-pointer KV state "
                         "instead of the paged per-slot block tables (A/B)")
    ap.add_argument("--no-slot-routing", action="store_true",
                    help="legacy global-chain routing — one chain for "
                         "every slot per cycle and O(pool) admission "
                         "prefill — instead of per-slot lazy chains (A/B)")
    ap.add_argument("--no-fused", action="store_true",
                    help="legacy host-orchestrated per-op speculation "
                         "cycles instead of the device-resident fused "
                         "cycle program (A/B)")
    ap.add_argument("--profile-every", type=int, default=16,
                    help="run an unfused profiling cycle every N cycles "
                         "to refresh the scheduler's per-op timings "
                         "(0 = never)")
    ap.add_argument("--workload", default="poisson",
                    choices=["poisson", "burst", "trace"],
                    help="arrival process: Poisson open loop (default), "
                         "MMPP bursty (ON/OFF clumps at the same offered "
                         "load), or JSONL trace replay")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="JSONL trace for --workload trace (see "
                         "data/workload.py save_trace/load_trace)")
    ap.add_argument("--ttft-slo", type=float, default=None, metavar="S",
                    help="per-request time-to-first-token SLO in seconds; "
                         "setting any SLO turns on the goodput-aware "
                         "chain search and EDF admission")
    ap.add_argument("--tpot-slo", type=float, default=None, metavar="S",
                    help="per-request time-per-output-token SLO in "
                         "seconds")
    ap.add_argument("--shed", action="store_true",
                    help="shed queued requests whose TTFT deadline "
                         "cannot be met anymore (needs --ttft-slo)")
    ap.add_argument("--mesh", default=None, metavar="DXM",
                    help="place the pool on a ('data','model') device "
                         "mesh, e.g. 2x4: the target is tensor-parallel "
                         "over the model axis, drafts are replicated; "
                         "virtual CPU devices are spawned to fill the "
                         "mesh when needed")
    args = ap.parse_args()
    if args.workload == "trace" and not args.trace_file:
        ap.error("--workload trace requires --trace-file")
    if args.shed and args.ttft_slo is None:
        ap.error("--shed needs --ttft-slo (deadline to shed against)")

    pool, corpus = build_trained_pool(steps=args.steps)

    tmo = run(pool, corpus, args, "TMO (target only)",
              dict(adaptive=False, fixed_chain=("demo-7b",),
                   fixed_window=1))
    ssd = run(pool, corpus, args, "SSD-Smallest (static)",
              dict(adaptive=False, fixed_chain=("demo-68m", "demo-7b"),
                   fixed_window=4))
    tree_kw = {}
    if args.tree:
        sst = run(pool, corpus, args, f"SSD-Tree {args.tree} (static)",
                  dict(adaptive=False,
                       fixed_chain=("demo-68m", "demo-7b"),
                       fixed_tree=args.tree))
        tree_kw = dict(tree_shapes=(args.tree,))
    ours = run(pool, corpus, args, "SpecRouter (ours)",
               dict(adaptive=True, **tree_kw))
    eaf = f"\nEAF (vs TMO): SSD {tmo.avg_tpot_s/ssd.avg_tpot_s:.2f}x | "
    if args.tree:
        eaf += f"SSD-Tree {tmo.avg_tpot_s/sst.avg_tpot_s:.2f}x | "
    eaf += f"SpecRouter {tmo.avg_tpot_s/ours.avg_tpot_s:.2f}x"
    print(eaf)


if __name__ == "__main__":
    main()
