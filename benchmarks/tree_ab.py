"""Linear-vs-tree speculation A/B on the synthetic workload.

Same trained demo pool, same prompts, same seed: a linear window draft
against token-tree drafts of equal depth (so every mode can commit at most
depth+1 tokens per cycle).  Reports accepted length per cycle and decode
tokens/s, and asserts the greedy output-quality guarantee holds in every
mode (tree commits are bit-identical to the linear stream).

Output CSV: tree_ab,<shape>,<nodes>,<steps>,<acc_per_cycle>,<tok_per_s>,
<bit_identical>.  ``shape`` is ``W<w>`` for the linear baseline and the
``b0xb1x...`` branching profile for trees.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import ChainRouter, TokenTree
from repro.train.pool import build_trained_pool

SHAPES = ("1x1x1x1", "2x1x1x1", "2x2x1x1", "3x2x1x1")


def run_mode(pool, prompts, lens, max_new: int, chain,
             window: Optional[int] = None, tree=None,
             seed: int = 0) -> Dict:
    kw = dict(adaptive=False, fixed_chain=chain)
    if tree is not None:
        kw["fixed_tree"] = tree
    else:
        kw["fixed_window"] = window
    router = ChainRouter(pool, chain[-1], greedy=True, seed=seed, **kw)
    # warmup populates jit caches (tree programs specialize per shape)
    router.generate(prompts, lens, min(6, max_new), request_id="warm")
    out = router.generate(prompts, lens, max_new, request_id="run")
    wall = sum(out.cycle_wall_s)
    return dict(
        generated=out.generated,
        steps=out.steps,
        committed=out.committed_tokens,
        acc=float(np.mean(out.acceptance_lengths)),
        tok_s=out.committed_tokens / max(wall, 1e-9),
    )


def main(shapes: Sequence[str] = SHAPES, max_new: int = 24,
         batch: int = 4, print_csv: bool = True) -> List[Dict]:
    pool, corpus = build_trained_pool(verbose=False)
    prompts, lens = corpus.prompts(batch, 10, 24, seed=21)
    chain = ("demo-68m", "demo-7b")
    depth = TokenTree.parse(shapes[0]).depth_levels
    assert all(TokenTree.parse(s).depth_levels == depth for s in shapes), \
        "A/B shapes must share a depth so per-cycle commit caps match"

    base = run_mode(pool, prompts, lens, max_new, chain, window=depth)
    rows = [dict(shape=f"W{depth}", nodes=depth, **base, identical=True)]
    for s in shapes:
        tree = TokenTree.parse(s)
        r = run_mode(pool, prompts, lens, max_new, chain, tree=tree)
        ident = all(np.array_equal(a, b)
                    for a, b in zip(r["generated"], base["generated"]))
        rows.append(dict(shape=str(tree), nodes=tree.num_nodes, **r,
                         identical=ident))

    if print_csv:
        for row in rows:
            print(f"tree_ab,{row['shape']},{row['nodes']},{row['steps']},"
                  f"{row['acc']:.3f},{row['tok_s']:.1f},"
                  f"{int(row['identical'])}")
    assert all(r["identical"] for r in rows), \
        "tree mode broke greedy bit-equality"
    return rows


if __name__ == "__main__":
    main()
