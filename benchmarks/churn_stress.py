"""Capacity-stress churn benchmark: one long-lived slot plus admission
churn — the workload that exhausted the contiguous shared-pointer KV cache
(forced defragments, full reprefill rebuilds on the hot path).

Paged mode must finish with ZERO ``defrag.*`` / ``reprefill.*`` escape
counters and bounded pool usage; the contiguous A/B on the same sizing
shows the pathology.  Run as a CI smoke (``--assert`` exits nonzero if the
paged run trips an escape hatch or the streams diverge from target-only
greedy decoding).

    PYTHONPATH=src python -m benchmarks.churn_stress --assert

Output CSV: churn,<mode>,<cycles>,<defrags>,<reprefills>,<peak_slots>,
<committed>,<wall_s>.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChainRouter, ModelPool
from repro.core.state_manager import StateManager
from repro.models import ModelConfig
from repro.models import kv_cache as kvc
from repro.models.model import LanguageModel


def tiny_pool() -> ModelPool:
    p = ModelPool()
    for (n, L, d, s) in [("s", 2, 32, 1), ("t", 3, 48, 2)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=64, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


def run_churn(pool: ModelPool, paged: bool, n_shorts: int, long_budget: int,
              max_len: int) -> Tuple[List[np.ndarray], Dict, float, int]:
    rng = np.random.default_rng(7)
    long_prompt = rng.integers(1, 64, size=8).astype(np.int64)
    shorts = [rng.integers(1, 64, size=6).astype(np.int64)
              for _ in range(n_shorts)]
    router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("s", "t"),
                         fixed_window=3, paged=paged)
    sess = router.start_session(2, max_len, session_id="churn")
    sid = StateManager.key("t", "churn")
    peak = 0

    def track():
        nonlocal peak
        st = router.states.get(sid)
        used = (int(kvc.blocks_in_use(st)) * st.block_size
                if isinstance(st, kvc.PagedModelState) else int(st.write_ptr))
        peak = max(peak, used)

    t0 = time.perf_counter()
    sess.admit(0, long_prompt, long_budget)
    outs = []
    for sp in shorts:
        sess.admit(1, sp, 4)
        while sess.active[1]:
            sess.run_cycle()
            track()
        outs.append(sess.retire(1))
    while sess.active[0]:
        sess.run_cycle()
        track()
    outs.insert(0, sess.retire(0))
    wall = time.perf_counter() - t0
    counters = dict(router.profiler.counters)
    sess.close()
    return outs, counters, wall, peak


def reference_streams(pool: ModelPool, n_shorts: int,
                      long_budget: int) -> List[np.ndarray]:
    """Target-only greedy decoding of the same requests (the bit-equality
    oracle for both churn modes)."""
    rng = np.random.default_rng(7)
    long_prompt = rng.integers(1, 64, size=8).astype(np.int64)
    shorts = [rng.integers(1, 64, size=6).astype(np.int64)
              for _ in range(n_shorts)]
    r = ChainRouter(pool, "t", adaptive=False, fixed_chain=("t",),
                    fixed_window=1)
    outs = [r.generate(long_prompt[None, :], np.array([8]), long_budget,
                       request_id="ref-long").generated[0]]
    for i, sp in enumerate(shorts):
        outs.append(r.generate(sp[None, :], np.array([6]), 4,
                               request_id=f"ref-{i}").generated[0])
    return outs


def main(n_shorts: int = 8, long_budget: int = 40, max_len: int = 128,
         check: bool = False) -> Dict[str, Dict]:
    pool = tiny_pool()
    rows = {}
    ref = reference_streams(pool, n_shorts, long_budget)
    for mode, paged in (("paged", True), ("contiguous", False)):
        outs, counters, wall, peak = run_churn(pool, paged, n_shorts,
                                               long_budget, max_len)
        defrags = sum(v for k, v in counters.items()
                      if k.startswith("defrag."))
        reprefills = sum(v for k, v in counters.items()
                         if k.startswith("reprefill."))
        exact = all(np.array_equal(a, b) for a, b in zip(outs, ref))
        rows[mode] = dict(cycles=counters.get("cycles", 0), defrags=defrags,
                          reprefills=reprefills, peak_slots=peak,
                          committed=int(sum(len(o) for o in outs)),
                          wall_s=wall, bit_exact=exact)
        print(f"churn,{mode},{int(rows[mode]['cycles'])},{int(defrags)},"
              f"{int(reprefills)},{peak},{rows[mode]['committed']},"
              f"{wall:.2f},{'exact' if exact else 'DIVERGED'}")
    if check:
        p = rows["paged"]
        assert p["defrags"] == 0 and p["reprefills"] == 0, (
            f"paged churn tripped capacity escapes: {p}")
        assert p["bit_exact"], "paged churn diverged from target-only greedy"
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--assert", dest="check", action="store_true",
                    help="exit nonzero if the paged run trips an escape "
                         "hatch or diverges from target-only decoding")
    ap.add_argument("--shorts", type=int, default=8)
    ap.add_argument("--long-budget", type=int, default=40)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()
    main(n_shorts=args.shorts, long_budget=args.long_budget,
         max_len=args.max_len, check=args.check)
