"""Paper §2.2 efficiency analysis validation (Eq. 2/3/4):

  1. acceptance probability α ≈ 1 − E[DTV(p, q)]     (Eq. 2)
  2. E[accepted]            ≈ (1 − α^{γ+1})/(1 − α) − 1-ish form (Eq. 3)
  3. speedup               ≈ (1 − α^{γ+1}) / ((1 − α)(γc + 1)) (Eq. 4)

Monte-Carlo rejection sampling vs formulas on synthetic (p, q) pairs.
Output CSV: analytic,<quantity>,<measured>,<predicted>.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import verification as ver


def simulate_acceptance(key, p_logits, q_logits, gamma: int,
                        trials: int = 2000):
    V = p_logits.shape[-1]
    q = jax.nn.softmax(q_logits)
    kd, kv = jax.random.split(key)
    draft = jax.random.categorical(
        kd, jnp.broadcast_to(q_logits, (trials, gamma, V)).reshape(-1, V)
    ).reshape(trials, gamma)
    vlogits = jnp.broadcast_to(p_logits, (trials, gamma + 1, V))
    cprobs = jnp.broadcast_to(q, (trials, gamma, V))
    res = ver.verify_sampling(draft, vlogits, cprobs, kv)
    return float(jnp.mean(res.num_accepted))


def main(print_csv: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []
    for i, scale in enumerate([0.3, 1.0, 2.5]):
        kp, kq, ks, key = jax.random.split(key, 4)
        V = 50
        p_logits = jax.random.normal(kp, (V,)) * 1.2
        q_logits = p_logits + jax.random.normal(kq, (V,)) * scale
        p = jax.nn.softmax(p_logits)
        q = jax.nn.softmax(q_logits)
        dtv = float(0.5 * jnp.sum(jnp.abs(p - q)))
        alpha_pred = 1.0 - dtv                       # Eq. 2
        # measured single-token acceptance rate
        acc1 = simulate_acceptance(ks, p_logits, q_logits, gamma=1)
        rows.append(("alpha", acc1, alpha_pred))
        if print_csv:
            print(f"analytic,alpha(scale={scale}),{acc1:.4f},"
                  f"{alpha_pred:.4f}")
        # Eq. 3: expected accepted for gamma=4 (note: per-position i.i.d.
        # approximation — the simulation uses the SAME p,q at every
        # position, matching the assumption exactly)
        gamma = 4
        accg = simulate_acceptance(ks, p_logits, q_logits, gamma=gamma)
        a = alpha_pred
        pred = a * (1 - a ** gamma) / (1 - a) if a < 1 else gamma
        rows.append(("accepted", accg, pred))
        if print_csv:
            print(f"analytic,E[accepted](g=4 scale={scale}),{accg:.3f},"
                  f"{pred:.3f}")
        # Eq. 4 speedup at c=0.1
        c = 0.1
        speed = (1 + accg) / (gamma * c + 1)
        speed_pred = (1 - a ** (gamma + 1)) / ((1 - a) * (gamma * c + 1)) \
            if a < 1 else (gamma + 1) / (gamma * c + 1)
        rows.append(("speedup", speed, speed_pred))
        if print_csv:
            print(f"analytic,speedup(c=0.1 scale={scale}),{speed:.3f},"
                  f"{speed_pred:.3f}")
    return rows


if __name__ == "__main__":
    main()
