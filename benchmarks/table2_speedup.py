"""Paper Table 2 reproduction: speed ratio relative to the autoregressive
baseline (TMO) vs batch size, for
  - Second-level SD   (static [draft, target]),
  - Third-level SD    (static [draft, mid, target]),
  - Third-level Ours  (SpecRouter adaptive).

Real wall-clock on the CPU-trained demo pool (same capability ordering as
the paper's Llama pool).  Output: CSV rows batch,method,ratio.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import ChainRouter
from repro.train.pool import build_trained_pool

BATCHES = (1, 4, 8, 16, 32, 64)
METHODS = {
    "TMO": dict(adaptive=False, fixed_chain=("demo-7b",), fixed_window=1),
    "second-level-sd": dict(adaptive=False,
                            fixed_chain=("demo-68m", "demo-7b"),
                            fixed_window=4),
    "third-level-sd": dict(adaptive=False,
                           fixed_chain=("demo-68m", "demo-1b", "demo-7b"),
                           fixed_window=4),
    "third-level-ours": dict(adaptive=True),
}


def tpot_for(pool, corpus, batch: int, router_kwargs, max_new: int = 24,
             seed: int = 5) -> float:
    """Steady-state TPOT: one warmup generation populates the jit caches
    (the paper measures decode speed, not compile time), then the timed
    run reuses the same router/executor."""
    prompts, lens = corpus.prompts(batch, 10, 24, seed=seed)
    router = ChainRouter(pool, "demo-7b", greedy=True, **router_kwargs)
    router.generate(prompts, lens, min(6, max_new), request_id=f"w{batch}")
    out = router.generate(prompts, lens, max_new, request_id=f"b{batch}")
    wall = sum(out.cycle_wall_s)
    return wall / max(out.committed_tokens, 1)


def main(batches=BATCHES, max_new: int = 24, repeats: int = 1,
         print_csv: bool = True) -> List[Dict]:
    pool, corpus = build_trained_pool(verbose=False)
    rows = []
    for B in batches:
        tpots = {}
        for name, kw in METHODS.items():
            vals = [tpot_for(pool, corpus, B, kw, max_new, seed=5 + r)
                    for r in range(repeats)]
            tpots[name] = float(np.mean(vals))
        for name in METHODS:
            if name == "TMO":
                continue
            ratio = tpots["TMO"] / tpots[name]
            rows.append(dict(batch=B, method=name, ratio=ratio,
                             tpot_s=tpots[name], tmo_tpot_s=tpots["TMO"]))
            if print_csv:
                print(f"table2,{B},{name},{ratio:.3f}")
    return rows


if __name__ == "__main__":
    main()
