"""Mixed-difficulty routing A/B: per-slot lazy chain routing (default)
vs the legacy global-chain engine (``slot_routing=False``) that routes
every slot through one chain per cycle and prefills the WHOLE model pool
at every admission — the O(pool) admission bug this A/B pins down.

Difficulty is a property of the REQUEST, engineered without training:

  * the target is a "layered twin" — an L-layer transformer whose last
    L-2 residual blocks have zeroed out-projections, so it computes
    exactly the function of its first two blocks at ~L/2 the wall cost;
  * the draft shares the target's embedding / first two blocks / head,
    except the embedding row of one HARD_TOKEN, which is heavily
    perturbed.  Prompts avoiding HARD_TOKEN see draft ≡ target
    (acceptance ≈ 1, easy); prompts containing it diverge at every
    position (acceptance ≈ chance, hard);
  * two larger random decoys complete the pool: never worth scheduling,
    so the lazy engine never materializes them — while the baseline's
    admission prefills them for every single request.

The per-slot arm must be >= the baseline on goodput or p95 TTFT, with
BOTH arms' greedy streams bit-identical to target-only decoding, and the
lazy arm's admission counters must show zero decoy prefills (O(chain)
work per admit).  Run as a CI smoke:

    python -m benchmarks.routing_ab --assert

Output CSV: routing,<mode>,<goodput_tps>,<p95_ttft_s>,<avg_ttft_s>,
<avg_queue_s>,<decoy_prefills>,<bit_exact>.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChainRouter, ModelPool
from repro.data import Request, streams_bit_exact
from repro.models import ModelConfig
from repro.models.model import LanguageModel
from repro.serving import ServingEngine

HARD_TOKEN = 63
VOCAB = 64
DECOYS = ("aux1", "aux2")


def build_pool(seed: int = 0) -> ModelPool:
    p = ModelPool()
    dm, heads, kv, ff = 48, 4, 2, 96
    tgt_cfg = ModelConfig(name="tgt", arch_type="dense", num_layers=6,
                          d_model=dm, num_heads=heads, num_kv_heads=kv,
                          d_ff=ff, vocab_size=VOCAB, tie_embeddings=False,
                          dtype=jnp.float32)
    tgt_lm = LanguageModel(tgt_cfg)
    tgt_params, tgt_axes = tgt_lm.init(jax.random.PRNGKey(seed))
    # zero the out-projections of blocks 2..5: those residual blocks
    # become identity, so the 6-layer target computes its first-2-block
    # function at 3x the wall cost (a faithful stand-in for a big target)
    blocks = jax.tree.map(np.array, tgt_params["blocks"])
    blocks["attn"]["o"]["w"][2:] = 0
    blocks["mlp"]["down"]["w"][2:] = 0
    tgt_params = {**tgt_params, "blocks": blocks}
    p.register(tgt_cfg, params=tgt_params, param_axes=tgt_axes)

    drf_cfg = ModelConfig(name="drf", arch_type="dense", num_layers=2,
                          d_model=dm, num_heads=heads, num_kv_heads=kv,
                          d_ff=ff, vocab_size=VOCAB, tie_embeddings=False,
                          dtype=jnp.float32)
    drf_lm = LanguageModel(drf_cfg)
    embed = np.array(tgt_params["embed"])
    noise = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 99),
                                         (dm,)), np.float32)
    embed[HARD_TOKEN] = embed[HARD_TOKEN] + 0.5 * noise
    drf_params = {
        "embed": embed.astype(np.float32),
        "blocks": jax.tree.map(lambda x: np.array(x[:2]), blocks),
        "final_norm": tgt_params["final_norm"],
        "lm_head": tgt_params["lm_head"],
    }
    p.register(drf_cfg, params=drf_params, param_axes=drf_lm.param_axes())

    for i, name in enumerate(DECOYS):
        cfg = ModelConfig(name=name, arch_type="dense", num_layers=6,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=VOCAB, tie_embeddings=False,
                          dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(seed + 10 + i))
        p.register(cfg, params=params, param_axes=axes)
    return p


def make_requests(n: int, seed: int = 3, budget: int = 6,
                  plen: int = 8) -> List[Request]:
    """Alternating easy/hard arrivals, closely spaced (slot churn)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        hard = i % 2 == 1
        prompt = rng.integers(1, HARD_TOKEN, size=plen).astype(np.int64)
        if hard:   # several HARD_TOKENs -> every position diverges
            prompt[rng.choice(plen, size=plen // 2, replace=False)] = \
                HARD_TOKEN
        reqs.append(Request(request_id=f"{'hard' if hard else 'easy'}-{i}",
                            arrival_s=0.05 * i, prompt=prompt,
                            max_new_tokens=budget, dataset="mixed"))
    return reqs


def reference_streams(pool: ModelPool,
                      reqs: List[Request]) -> List[np.ndarray]:
    r = ChainRouter(pool, "tgt", adaptive=False, fixed_chain=("tgt",),
                    fixed_window=1)
    outs = []
    for i, q in enumerate(reqs):
        outs.append(r.generate(q.prompt[None, :], np.array([len(q.prompt)]),
                               q.max_new_tokens,
                               request_id=f"ref{i}").generated[0])
    return outs


def run_arm(pool: ModelPool, slot_routing: bool, n_reqs: int,
            ref: List[np.ndarray]) -> Dict:
    eng = ServingEngine(
        pool, "tgt", batch_size=3, slo_latency_s=600.0,
        router_kwargs=dict(
            adaptive=True, slot_routing=slot_routing, windows=(2, 3, 4),
            # same-arch pool: wall time scales ~linearly with params, so
            # the cold-start decode prior should too (default 0.5 is for
            # heterogeneous pools)
            scheduler_kwargs=dict(capability_exponent=1.0)))
    # warm every jitted shape so compile time is not billed to the
    # measured clock (identical warmup for both arms).  Cold-start EMAs
    # are compile-time-polluted, so the scheduler may explore a decoy
    # chain for one cycle during warmup before evidence kills it — the
    # O(chain) invariant is asserted over the MEASURED phase.
    eng.run(make_requests(3, seed=11))
    def decoy_ops():
        return sum(v for k, v in eng._router.profiler.counters.items()
                   if any(k.startswith(f"{op}.{d}")
                          for op in ("prefill", "insert", "admit")
                          for d in DECOYS))
    warm_decoy = decoy_ops()
    m = eng.run(reqs := make_requests(n_reqs))
    return dict(metrics=m, bit_exact=streams_bit_exact(reqs, ref),
                decoy_prefills=int(decoy_ops() - warm_decoy))


def main(n_reqs: int = 10, check: bool = False) -> Dict[str, Dict]:
    pool = build_pool()
    ref = reference_streams(pool, make_requests(n_reqs))
    rows = {}
    for mode, slot_routing in (("per-slot", True), ("global", False)):
        res = run_arm(pool, slot_routing, n_reqs, ref)
        m = res["metrics"]
        rows[mode] = res
        print(f"routing,{mode},{m.goodput_tps:.2f},{m.p95_ttft_s:.3f},"
              f"{m.avg_ttft_s:.3f},{m.avg_queue_s:.3f},"
              f"{res['decoy_prefills']},"
              f"{'exact' if res['bit_exact'] else 'DIVERGED'}")
    if check:
        a, b = rows["per-slot"], rows["global"]
        assert a["bit_exact"], "per-slot arm diverged from target-only"
        assert b["bit_exact"], "global arm diverged from target-only"
        assert a["decoy_prefills"] == 0, (
            f"lazy admission touched decoy models "
            f"({a['decoy_prefills']} ops) — O(chain) invariant broken")
        ma, mb = a["metrics"], b["metrics"]
        assert (ma.goodput_tps >= mb.goodput_tps
                or ma.p95_ttft_s <= mb.p95_ttft_s), (
            f"per-slot routing lost on BOTH goodput "
            f"({ma.goodput_tps:.2f} vs {mb.goodput_tps:.2f} tps) and p95 "
            f"TTFT ({ma.p95_ttft_s:.3f} vs {mb.p95_ttft_s:.3f} s)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--assert", dest="check", action="store_true",
                    help="exit nonzero unless per-slot >= global on "
                         "goodput or p95 TTFT, both arms bit-exact, and "
                         "lazy admission never touches decoy models")
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()
    main(n_reqs=args.requests, check=args.check)
