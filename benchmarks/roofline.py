"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch × shape × mesh) from the dry-run's compiled artifacts.

  compute term    = HLO_FLOPs(loop-aware, per device) / peak_FLOP/s
  memory term     = HLO_bytes(loop-aware, per device) / HBM_bw
  collective term = collective_bytes(per device)      / link_bw

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference fwd) and the
useful-compute ratio.  Emits benchmarks/roofline_summary.{md,json}.

Output CSV: roofline,<arch>,<shape>,<mesh>,<t_comp>,<t_mem>,<t_coll>,<dom>.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs import INPUT_SHAPES, get_config, effective_shape
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16)

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "dryrun_results")


def model_flops_per_device(rec: Dict) -> float:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    seq, batch, _ = effective_shape(cfg, shape)
    n_active = cfg.active_param_count()
    if rec["kind"] == "train":
        tokens = seq * batch
        total = 6.0 * n_active * tokens
    elif rec["kind"] == "prefill":
        total = 2.0 * n_active * seq * batch
    else:  # decode: one token per row
        total = 2.0 * n_active * batch
    return total / rec.get("devices", 256)


def analyze_record(rec: Dict) -> Dict:
    flops = rec.get("flops_loop_aware", rec.get("flops", 0.0))
    hbm = rec.get("hbm_bytes_loop_aware", rec.get("bytes_accessed", 0.0))
    coll = rec.get("collective_bytes_loop_aware",
                   rec.get("collectives", {}).get("total", 0.0))
    t_comp = flops / PEAK_FLOPS_BF16
    t_mem = hbm / HBM_BW
    t_coll = coll / ICI_BW_PER_LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    ratio = mf / flops if flops else 0.0
    bound_time = max(terms.values())
    suggestions = {
        "compute": "increase per-chip arithmetic intensity (larger "
                   "microbatch / fuse elementwise into matmuls); compute-"
                   "bound is the healthy end state",
        "memory": "cut HBM traffic: remat policy, bf16 accumulators, "
                  "ring-buffer SWA cache, fused attention kernel "
                  "(avoid materialized scores), chunked loss",
        "collective": "reshard to cut cross-chip traffic: FSDP->TP swap, "
                      "overlap collectives with compute, reduce-scatter "
                      "instead of all-reduce+slice, expert-parallel "
                      "all-to-all fusion",
    }
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec["kind"], t_compute_s=t_comp, t_memory_s=t_mem,
        t_collective_s=t_coll, dominant=dominant,
        model_flops_per_dev=mf, hlo_flops_per_dev=flops,
        useful_compute_ratio=ratio,
        bound_time_s=bound_time,
        peak_bytes_per_device=rec.get("peak_bytes_per_device", 0),
        suggestion=suggestions[dominant],
    )


def main(print_csv: bool = True, mesh: str = "single") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        rec = json.load(open(f))
        if rec.get("skipped") or not rec.get("ok"):
            continue
        r = analyze_record(rec)
        rows.append(r)
        if print_csv:
            print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                  f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
                  f"{r['t_collective_s']:.3e},{r['dominant']}")
    out = os.path.join(HERE, f"roofline_summary_{mesh}.json")
    json.dump(rows, open(out, "w"), indent=1)

    md = [f"| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
          f"dominant | useful-FLOP ratio | peak GiB/dev |",
          "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_compute_ratio']:.2f} | "
            f"{r['peak_bytes_per_device']/2**30:.1f} |")
    with open(os.path.join(HERE, f"roofline_summary_{mesh}.md"), "w") as fh:
        fh.write("\n".join(md) + "\n")
    return rows


if __name__ == "__main__":
    main()
