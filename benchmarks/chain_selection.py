"""Paper Figure 2: dynamic model-chain selection.  Prints the scheduler's
predicted T_eff for every candidate (chain, W) from live profiled metrics
and verifies the selected chain is the argmin.

Output CSV: chain_selection,<chain>,<W>,<predicted_ms_per_token>,<selected>.
"""
from __future__ import annotations

from repro.core import ChainRouter
from repro.train.pool import build_trained_pool


def main(print_csv: bool = True):
    pool, corpus = build_trained_pool(verbose=False)
    prompts, lens = corpus.prompts(2, 12, 20, seed=17)
    router = ChainRouter(pool, "demo-7b", greedy=True, adaptive=True)
    router.generate(prompts, lens, 16, request_id="fig2")
    choice = router.scheduler.get_optimal_chain()
    rows = []
    for (chain, w, tr), t in sorted(choice.table.items(),
                                    key=lambda kv: kv[1]):
        sel = (chain, w, tr) == (choice.chain, choice.window, choice.tree)
        shape = str(tr) if tr is not None else "linear"
        rows.append(dict(chain=chain, window=w, tree=shape, t_eff=t,
                         selected=sel))
        if print_csv:
            print(f"chain_selection,{'->'.join(chain)},{w},{shape},"
                  f"{t*1e3:.3f},{int(sel)}")
    assert rows[0]["selected"], "scheduler did not pick the argmin"
    return rows


if __name__ == "__main__":
    main()
