"""Benchmark harness entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,...,derived`` CSV per benchmark (see each module docstring).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller batch set / shorter workloads")
    ap.add_argument("--skip-serving", action="store_true")
    ap.add_argument("--no-continuous", action="store_true",
                    help="serve with the legacy stop-the-world batch-"
                         "formation engine instead of slot-level "
                         "continuous batching (A/B baseline)")
    ap.add_argument("--skip-tree", action="store_true",
                    help="skip the linear-vs-tree speculation A/B")
    ap.add_argument("--skip-routing", action="store_true",
                    help="skip the per-slot vs global-chain routing A/B")
    ap.add_argument("--tree-shapes", default=None,
                    help="comma-separated tree shapes for the A/B, e.g. "
                         "'1x1x1,2x1x1,2x2x1' (equal depth; default: a "
                         "depth-4 sweep)")
    args = ap.parse_args()

    from . import (analytic_model, chain_selection, roofline, routing_ab,
                   serving_metrics, table2_speedup, tree_ab)

    t0 = time.time()
    print("# analytic_model (paper Eq. 2/3/4)")
    analytic_model.main()

    print("# roofline (deliverable g - from dry-run artifacts)")
    for mesh in ("single", "multi"):
        try:
            roofline.main(mesh=mesh)
        except Exception as e:  # noqa: BLE001
            print(f"roofline,{mesh},unavailable,{e}")

    print("# chain_selection (paper Fig. 2)")
    chain_selection.main()

    print("# table2_speedup (paper Table 2)")
    batches = (1, 4, 8) if args.quick else (1, 4, 8, 16, 32, 64)
    table2_speedup.main(batches=batches,
                        max_new=12 if args.quick else 24)

    if not args.skip_tree:
        print("# tree_ab (linear vs token-tree speculation)")
        if args.tree_shapes:
            shapes = tuple(args.tree_shapes.split(","))
        else:
            shapes = (("1x1x1", "2x2x1") if args.quick else tree_ab.SHAPES)
        tree_ab.main(shapes=shapes, max_new=12 if args.quick else 24)

    if not args.skip_routing:
        print("# routing_ab (per-slot lazy routing vs global-chain)")
        routing_ab.main(n_reqs=6 if args.quick else 10)

    if not args.skip_serving:
        print("# serving_metrics (paper SS5 metrics)")
        serving_metrics.main(
            datasets=("gsm8k",) if args.quick
            else ("gsm8k", "humaneval", "mtbench", "mgsm"),
            duration=6.0 if args.quick else 12.0,
            continuous=not args.no_continuous)

    print(f"# total bench time: {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
