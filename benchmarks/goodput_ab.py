"""Goodput A/B under bursty traffic: SLO-aware serving (goodput-objective
chain search + EDF admission + TTFT shed policy) vs the latency-only
scheduler on the SAME arrival trace.

Workload shape — calibrated from a PILOT run of the burst on this
machine, so the A/B is machine-speed invariant:

  * a BURST of ``N_BURST`` requests arriving near-simultaneously onto a
    2-slot engine — 5x oversubscribed.  The pilot measures each queue
    position's TTFT; the SLO is placed between the head's and the tail's
    measured TTFT, so the head can meet it and the tail is doomed the
    moment it arrives;
  * a TRICKLE of ``N_TRICKLE`` requests arriving mid-drain (0.35-0.65 of
    the pilot's burst drain time) — each meets its SLO easily IF a slot
    frees up in time.

The latency-only arm serves the doomed burst tail anyway (maximizing raw
token throughput), so the trickle queues behind guaranteed SLO misses
and misses too.  The SLO-aware arm sheds the doomed tail before it is
ever admitted (those requests are misses in BOTH arms) and gives its
slots to the trickle, whose first tokens then land inside SLO —
strictly higher per-request SLO attainment (SpecServe's goodput metric)
from the same offered load.

Every SERVED request must remain bit-identical to target-only greedy
decoding in both arms (speculative decoding is lossless; SLO-awareness
only changes WHAT is scheduled, never what a served request gets).

Pool: a layered-twin target (the routing_ab trick — last 4 residual
blocks zeroed, so the 6-layer model computes its first-2-block function
at 3x the wall cost) plus a 2-layer draft sharing those first two blocks
exactly: acceptance ~= 1, so speculation is clearly profitable when idle
and the goodput objective's shrink-to-target-only under pressure is a
real trade, not a free win.  No decoy models: compile coverage must be
deterministic here (every program the measured phase can touch is
compiled during warmup — both arms warm through a queued burst, which
drives the SLO-aware scheduler through BOTH its regimes).

Run as a CI smoke:

    python -m benchmarks.goodput_ab --assert --json goodput_ab.json

Output CSV: goodput,<arm>,<slo_attainment>,<slo_goodput_rps>,
<p95_ttft_s>,<num_shed>,<bit_exact>.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ChainRouter, ModelPool
from repro.data import Request, streams_bit_exact
from repro.models import ModelConfig
from repro.models.model import LanguageModel
from repro.serving import ServingEngine

VOCAB = 64
BUDGET = 16     # tokens per request
PLEN = 8        # prompt length (one jitted shape for everything)
N_BURST = 10
N_TRICKLE = 4
SLOTS = 2
# frequent per-op profiling cycles: every program variant (fused AND
# per-op, both chain regimes) gets compiled during warmup, so no compile
# wall can land inside the measured clock of either arm
PROFILE_EVERY = 4


def build_pool(seed: int = 0) -> ModelPool:
    p = ModelPool()
    dm, heads, kv, ff = 64, 4, 2, 128
    tgt_cfg = ModelConfig(name="tgt", arch_type="dense", num_layers=6,
                          d_model=dm, num_heads=heads, num_kv_heads=kv,
                          d_ff=ff, vocab_size=VOCAB, tie_embeddings=False,
                          dtype=jnp.float32)
    tgt_lm = LanguageModel(tgt_cfg)
    tgt_params, tgt_axes = tgt_lm.init(jax.random.PRNGKey(seed))
    # layered twin: zero the out-projections of blocks 2..5 so the
    # 6-layer target computes its first-2-block function at 3x the wall
    blocks = jax.tree.map(np.array, tgt_params["blocks"])
    blocks["attn"]["o"]["w"][2:] = 0
    blocks["mlp"]["down"]["w"][2:] = 0
    tgt_params = {**tgt_params, "blocks": blocks}
    p.register(tgt_cfg, params=tgt_params, param_axes=tgt_axes)

    # draft = the target's live prefix: same embedding / first two
    # blocks / head -> draft distribution == target distribution, so
    # acceptance ~= 1 and deep speculation is the clear idle optimum
    drf_cfg = ModelConfig(name="drf", arch_type="dense", num_layers=2,
                          d_model=dm, num_heads=heads, num_kv_heads=kv,
                          d_ff=ff, vocab_size=VOCAB, tie_embeddings=False,
                          dtype=jnp.float32)
    drf_lm = LanguageModel(drf_cfg)
    drf_params = {
        "embed": np.array(tgt_params["embed"]),
        "blocks": jax.tree.map(lambda x: np.array(x[:2]), blocks),
        "final_norm": tgt_params["final_norm"],
        "lm_head": tgt_params["lm_head"],
    }
    p.register(drf_cfg, params=drf_params, param_axes=drf_lm.param_axes())
    return p


def make_requests(n_burst: int = N_BURST, n_trickle: int = N_TRICKLE,
                  ttft_slo: Optional[float] = None,
                  tpot_slo: Optional[float] = None,
                  trickle_at: Optional[Sequence[float]] = None,
                  seed: int = 3) -> List[Request]:
    """Burst + trickle arrivals.  Prompts depend only on ``seed`` and the
    counts — SLOs and trickle times come from pilot calibration, so
    reference streams can be computed up front and reused for every
    arm."""
    rng = np.random.default_rng(seed)
    if trickle_at is None:
        trickle_at = [0.0] * n_trickle   # placeholder (reference pass
                                         # only reads prompts/budgets)
    reqs = []
    for i in range(n_burst):
        prompt = rng.integers(1, VOCAB, size=PLEN).astype(np.int64)
        reqs.append(Request(f"burst-{i}", 0.004 * i, prompt, BUDGET,
                            "burst", ttft_slo_s=ttft_slo,
                            tpot_slo_s=tpot_slo))
    for k in range(n_trickle):
        prompt = rng.integers(1, VOCAB, size=PLEN).astype(np.int64)
        reqs.append(Request(f"trickle-{k}", float(trickle_at[k]), prompt,
                            BUDGET, "trickle", ttft_slo_s=ttft_slo,
                            tpot_slo_s=tpot_slo))
    return reqs


def reference_pass(pool: ModelPool,
                   reqs: List[Request]) -> List[np.ndarray]:
    """Target-only greedy streams — the bit-equality oracle."""
    r = ChainRouter(pool, "tgt", adaptive=False, fixed_chain=("tgt",),
                    fixed_window=1)
    outs = []
    for i, q in enumerate(reqs):
        res = r.generate(q.prompt[None, :], np.array([len(q.prompt)]),
                         q.max_new_tokens, request_id=f"ref{i}")
        outs.append(res.generated[0])
    return outs


def _engine(pool: ModelPool, slo_aware: bool,
            shed_policy: str) -> ServingEngine:
    return ServingEngine(
        pool, "tgt", batch_size=SLOTS, slo_latency_s=600.0,
        slo_aware=slo_aware, shed_policy=shed_policy,
        router_kwargs=dict(
            # a single speculation window pins the jitted-program set to
            # exactly {(drf,tgt) W4, (tgt,) W1}: the warmup burst compiles
            # both, so no compile wall can land inside the measured clock
            # of either arm (the graded window shrink is pinned by
            # tests/test_slo_scheduling.py; this A/B needs the binary
            # deep-vs-target-only trade)
            adaptive=True, windows=(4,),
            profile_every=PROFILE_EVERY,
            scheduler_kwargs=dict(capability_exponent=1.0)))


def _warm(eng: ServingEngine) -> None:
    """Queued no-SLO burst: 6 requests onto 2 slots queue 4 deep, so a
    goodput-aware engine sweeps through its pressure regime (target-only
    cycles) AND, once the queue drains, the idle regime (deep
    speculation) — every fused and per-op program either arm can touch
    in the measured phase compiles here.  Afterwards the cycle-latency
    EMA is reset: compile walls must not leak into the load signal or
    the shed policy's wait estimate."""
    eng.run(make_requests(6, 0, seed=11))
    eng._router.profiler.emas.pop(("cycle_wall", "session"), None)


def pilot(pool: ModelPool):
    """Burst-only pilot on a warmed latency-only engine: per-queue-
    position TTFTs and total drain time.  These place the SLO (between
    the head's and tail's TTFT) and the trickle arrivals (mid-drain) so
    the A/B's structure survives machine-speed differences."""
    eng = _engine(pool, slo_aware=False, shed_policy="none")
    _warm(eng)
    reqs = make_requests(N_BURST, 0)                 # measured burst
    eng.run(reqs)
    ttfts = [r.ttft for r in reqs]                   # queue-position order
    drain = max(r.finish_s for r in reqs)
    return ttfts, drain


def run_arm(pool: ModelPool, slo_aware: bool, shed_policy: str,
            reqs: List[Request], ref: List[np.ndarray]) -> Dict:
    eng = _engine(pool, slo_aware, shed_policy)
    _warm(eng)
    m = eng.run(reqs)
    return dict(metrics=m, reqs=reqs,
                bit_exact=streams_bit_exact(reqs, ref))


def main(check: bool = False, out_json: Optional[str] = None,
         verbose: bool = False) -> Dict[str, Dict]:
    pool = build_pool()
    ref = reference_pass(pool, make_requests())
    ttfts, drain = pilot(pool)
    # SLO midway between the second pair's and third pair's measured
    # TTFT: burst positions 0..3 can meet it, 4..9 cannot — and neither
    # can the trickle once it queues behind the whole burst, since its
    # wait then exceeds a full burst-pair service interval
    ttft_slo = 0.5 * (ttfts[SLOTS + 1] + ttfts[SLOTS * 2])
    # per-token SLO is generous (actual TPOT is a small fraction of the
    # request's service time): present to exercise the per-slot
    # feasibility term, never the deciding factor here
    tpot_slo = ttft_slo
    trickle_at = [(0.35 + 0.1 * k) * drain for k in range(N_TRICKLE)]
    print(f"# pilot: burst drain {drain:.2f}s, TTFT SLO {ttft_slo:.2f}s, "
          f"trickle at {[round(t, 2) for t in trickle_at]}")
    rows = {}
    for arm, slo_aware, shed in (("slo-aware", True, "ttft"),
                                 ("latency-only", False, "none")):
        reqs = make_requests(ttft_slo=ttft_slo, tpot_slo=tpot_slo,
                             trickle_at=trickle_at)
        res = run_arm(pool, slo_aware, shed, reqs, ref)
        m = res["metrics"]
        rows[arm] = res
        print(f"goodput,{arm},{m.request_slo_attainment:.3f},"
              f"{m.slo_goodput_rps:.2f},{m.p95_ttft_s:.3f},{m.num_shed},"
              f"{'exact' if res['bit_exact'] else 'DIVERGED'}")
        if verbose:
            for r in reqs:
                print(f"#   {r.request_id}: ttft={r.ttft:.2f} "
                      f"shed={r.shed} met={r.slo_met}")
    if out_json:
        payload = {"pilot_drain_s": drain, "ttft_slo_s": ttft_slo,
                   "n_burst": N_BURST, "n_trickle": N_TRICKLE,
                   "slots": SLOTS}
        for arm, res in rows.items():
            payload[arm] = {**res["metrics"].as_dict(),
                            "bit_exact": bool(res["bit_exact"])}
        with open(out_json, "w") as f:
            json.dump(payload, f, indent=2)
    if check:
        a = rows["slo-aware"]
        b = rows["latency-only"]
        assert a["bit_exact"], "SLO-aware arm diverged from target-only"
        assert b["bit_exact"], "latency-only arm diverged from target-only"
        ma, mb = a["metrics"], b["metrics"]
        assert mb.request_slo_attainment < 1.0, (
            "latency-only arm met every SLO — the calibrated workload is "
            "not stressing the engine; the A/B is vacuous")
        assert (ma.request_slo_attainment > mb.request_slo_attainment
                or (ma.request_slo_attainment == mb.request_slo_attainment
                    and ma.p95_ttft_s < mb.p95_ttft_s)), (
            f"SLO-aware serving did not win goodput: attainment "
            f"{ma.request_slo_attainment:.3f} vs "
            f"{mb.request_slo_attainment:.3f}, p95 TTFT "
            f"{ma.p95_ttft_s:.3f} vs {mb.p95_ttft_s:.3f} s")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--assert", dest="check", action="store_true",
                    help="exit nonzero unless the SLO-aware arm beats "
                         "latency-only on per-request SLO attainment (or "
                         "ties with lower p95 TTFT), both arms bit-exact "
                         "to target-only decoding")
    ap.add_argument("--json", dest="out_json", default=None,
                    help="write both arms' metrics to this JSON file "
                         "(CI artifact)")
    ap.add_argument("--verbose", action="store_true",
                    help="per-request TTFT/shed/SLO outcome lines")
    args = ap.parse_args()
    main(check=args.check, out_json=args.out_json, verbose=args.verbose)
