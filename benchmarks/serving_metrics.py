"""Paper §5 metrics benchmark: goodput, request throughput, TTFT, TPOT,
EAF, SLO attainment under Poisson load — per dataset profile
(GSM8K / HumanEval / MTBench / MGSM), SpecRouter vs TMO vs SSD.

Requests are served with slot-level continuous batching by default
(``continuous=False`` reproduces the legacy stop-the-world batch-formation
engine for A/B comparison — ``benchmarks/run.py --no-continuous``).
Queueing delay is billed to TTFT in both modes.

Output CSV: serving,<dataset>,<method>,<goodput>,<ttft>,<p95_ttft>,
<tpot>,<slo>,<queue>,<eaf>.
"""
from __future__ import annotations

from typing import Dict, List

from repro.data import make_workload
from repro.serving import ServingEngine
from repro.train.pool import build_trained_pool

METHODS = {
    "tmo": dict(adaptive=False, fixed_chain=("demo-7b",), fixed_window=1),
    "ssd-smallest": dict(adaptive=False,
                         fixed_chain=("demo-68m", "demo-7b"),
                         fixed_window=4),
    "specrouter": dict(adaptive=True),
}


def main(datasets=("gsm8k", "humaneval", "mtbench", "mgsm"),
         rate: float = 0.5, duration: float = 12.0, batch: int = 4,
         print_csv: bool = True, continuous: bool = True) -> List[Dict]:
    pool, corpus = build_trained_pool(verbose=False)
    rows = []
    for ds in datasets:
        base_tpot = None
        for method, kw in METHODS.items():
            reqs = make_workload(corpus, ds, rate, duration, seed=13)
            eng = ServingEngine(pool, "demo-7b", batch_size=batch,
                                slo_latency_s=45.0, router_kwargs=kw,
                                continuous=continuous)
            m = eng.run(reqs)
            if method == "tmo":
                base_tpot = m.avg_tpot_s
            eaf = base_tpot / m.avg_tpot_s if base_tpot else float("nan")
            rows.append(dict(dataset=ds, method=method,
                             continuous=continuous, **m.as_dict(), eaf=eaf))
            if print_csv:
                print(f"serving,{ds},{method},{m.goodput_tps:.1f},"
                      f"{m.avg_ttft_s:.3f},{m.p95_ttft_s:.3f},"
                      f"{m.avg_tpot_s:.4f},{m.slo_attainment:.3f},"
                      f"{m.avg_queue_s:.3f},{eaf:.2f}")
    return rows


if __name__ == "__main__":
    main()
