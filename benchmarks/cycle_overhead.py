"""Fused vs per-op cycle overhead A/B (device-resident speculative cycles).

Same pool, same prompts, same seed, linear AND tree groups: the
host-orchestrated per-op cycle (``fused=False`` — one jitted op dispatch +
one host sync per draft/verify/rollback step, plus full probability
tensors pulled to host every level) against the fused cycle executor
(``fused=True`` — one jitted program and ONE host transfer per cycle
group).  Measures

  * host-sync count per cycle (the profiler's ``host_sync`` counter —
    host-synchronizing op dispatches on the serving path), and
  * per-cycle wall time (median over the measured run's cycles),

and asserts greedy bit-equality between the arms.  The pool is built from
SMALL models on purpose: per-cycle latency is then dominated by dispatch
gaps and device→host transfers — exactly the orchestration overhead this
benchmark isolates (with big models the same absolute saving hides inside
model FLOPs; the host-sync count is the size-independent signal).

With ``--assert`` the fused arm must take strictly fewer host syncs per
cycle AND win the median per-cycle latency — the CI smoke for the
device-resident serving path.  Emits a ``BENCH_5.json`` perf snapshot so
later PRs have a baseline trajectory.

Output CSV: cycle_overhead,<mode>,<arm>,<steps>,<syncs_per_cycle>,
<cycle_ms_median>,<tok_per_s>,<bit_identical>.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

import numpy as np

from repro.core import ChainRouter, ModelPool


def build_bench_pool(vocab: int = 127) -> ModelPool:
    """A 3-deep dispatch-bound pool: small dense models so per-cycle wall
    time is orchestration, not FLOPs."""
    import jax
    import jax.numpy as jnp
    from repro.models import ModelConfig
    from repro.models.model import LanguageModel
    pool = ModelPool()
    for (n, L, d, s) in [("bench-68m", 2, 32, 1), ("bench-1b", 3, 48, 2),
                         ("bench-7b", 4, 64, 3)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=vocab, dtype=jnp.float32)
        params, axes = LanguageModel(cfg).init(jax.random.PRNGKey(s))
        pool.register(cfg, params=params, param_axes=axes)
    return pool


def run_arm(pool, prompts, lens, max_new: int, chain, fused: bool,
            window: Optional[int] = None, tree=None,
            profile_every: int = 16) -> Dict:
    kw = dict(adaptive=False, fixed_chain=chain, fused=fused,
              profile_every=profile_every)
    if tree is not None:
        kw["fixed_tree"] = tree
    else:
        kw["fixed_window"] = window
    router = ChainRouter(pool, chain[-1], greedy=True, seed=0, **kw)
    # warmup populates the jit caches (incl. the fused cycle programs) —
    # at the SAME max_new, so the measured run reuses every compiled
    # shape (generate() sizes the session state from the token budget)
    router.generate(prompts, lens, max_new, request_id="warm")
    sync0 = router.profiler.counters["host_sync"]
    out = router.generate(prompts, lens, max_new, request_id="run")
    syncs = router.profiler.counters["host_sync"] - sync0
    wall = sum(out.cycle_wall_s)
    return dict(
        generated=out.generated,
        steps=out.steps,
        committed=out.committed_tokens,
        syncs_per_cycle=syncs / max(out.steps, 1),
        cycle_ms_median=1e3 * float(np.median(out.cycle_wall_s)),
        tok_s=out.committed_tokens / max(wall, 1e-9),
    )


def main(max_new: int = 32, batch: int = 4, window: int = 4,
         tree: str = "2x2x1", do_assert: bool = False,
         out_json: str = "BENCH_5.json", print_csv: bool = True) -> Dict:
    import jax
    pool = build_bench_pool()
    prompts = np.array(jax.random.randint(jax.random.PRNGKey(7),
                                          (batch, 12), 0, 127))
    lens = np.array([12, 9, 11, 7][:batch] + [10] * max(batch - 4, 0))

    modes = {
        # 3-deep chain: the per-op path pays draft + 2 verifies +
        # 3 rollbacks + per-model capacity/gap reads every cycle
        "linear": dict(chain=("bench-68m", "bench-1b", "bench-7b"),
                       window=window),
        "tree": dict(chain=("bench-68m", "bench-7b"), tree=tree),
    }
    report: Dict[str, Dict] = {}
    for mode, mkw in modes.items():
        chain = mkw.pop("chain")
        arms = {}
        for arm, fused in (("unfused", False), ("fused", True)):
            arms[arm] = run_arm(pool, prompts, lens, max_new, chain,
                                fused, **mkw)
        ident = all(np.array_equal(a, b)
                    for a, b in zip(arms["fused"]["generated"],
                                    arms["unfused"]["generated"]))
        for arm in ("unfused", "fused"):
            r = arms[arm]
            if print_csv:
                print(f"cycle_overhead,{mode},{arm},{r['steps']},"
                      f"{r['syncs_per_cycle']:.2f},"
                      f"{r['cycle_ms_median']:.2f},{r['tok_s']:.1f},"
                      f"{int(ident)}")
            r.pop("generated")
        report[mode] = dict(**{a: arms[a] for a in arms},
                            bit_identical=ident)

    if out_json:
        with open(out_json, "w") as f:
            json.dump({"bench": "cycle_overhead", "max_new": max_new,
                       "batch": batch, "window": window, "tree": tree,
                       "modes": report}, f, indent=2)

    if do_assert:
        for mode, rep in report.items():
            f, u = rep["fused"], rep["unfused"]
            assert rep["bit_identical"], \
                f"{mode}: fused output diverged from the per-op path"
            assert f["syncs_per_cycle"] < u["syncs_per_cycle"], \
                (f"{mode}: fused path must take strictly fewer host syncs "
                 f"per cycle ({f['syncs_per_cycle']:.2f} vs "
                 f"{u['syncs_per_cycle']:.2f})")
            assert f["cycle_ms_median"] < u["cycle_ms_median"], \
                (f"{mode}: fused path must win median per-cycle latency "
                 f"({f['cycle_ms_median']:.2f}ms vs "
                 f"{u['cycle_ms_median']:.2f}ms)")
        print("cycle_overhead,assert,ok")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--assert", dest="do_assert", action="store_true",
                    help="fail unless the fused path takes strictly fewer "
                         "host syncs per cycle and wins median per-cycle "
                         "latency (both modes), with bit-equal output")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--tree", default="2x2x1")
    ap.add_argument("--out-json", default="BENCH_5.json")
    a = ap.parse_args()
    main(max_new=a.max_new, batch=a.batch, window=a.window, tree=a.tree,
         do_assert=a.do_assert, out_json=a.out_json)
