"""Re-run the loop-aware HLO analysis over stored .hlo.txt.gz artifacts
(no recompilation) and refresh the dryrun JSON records in place."""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "../src"))

from repro.launch import hlo_analysis  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results")


def main():
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        rec = json.load(open(f))
        gz = f.replace(".json", ".hlo.txt.gz")
        if rec.get("skipped") or not rec.get("ok") or not os.path.exists(gz):
            continue
        with gzip.open(gz, "rt") as fh:
            hlo = fh.read()
        la = hlo_analysis.analyze(hlo)
        rec.update(flops_loop_aware=la["flops"],
                   hbm_bytes_loop_aware=la["hbm_bytes"],
                   collective_bytes_loop_aware=la["collective_bytes"],
                   collectives_by_op=la["collectives"])
        json.dump(rec, open(f, "w"), indent=1)
        print(f"{rec['arch']:<17}{rec['shape']:<13}{rec['mesh']:<7}"
              f"flops={la['flops']:.2e} hbm={la['hbm_bytes']:.2e} "
              f"coll={la['collective_bytes']:.2e}")


if __name__ == "__main__":
    main()
