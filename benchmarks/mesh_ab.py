"""Single-device vs mesh-sharded serving A/B (placement-aware fused cycles).

Same dispatch-bound pool, same prompts, same seed, fused linear cycles:
the TRIVIAL placement (unmeshed — the legacy single-device path) against
the pool placed on a ``("data","model")`` mesh of 8 virtual CPU devices
(target tensor-parallel, drafts replicated — the serving default from
``auto_assign``).  Measures per arm

  * steady-state host syncs per fused cycle — the PR 5 one-transfer
    contract must SURVIVE the mesh: the commit slab moves between chain
    levels through device-side collectives, never through the host, so
    the count stays exactly 1 on both arms;
  * per-cycle wall time (median) and committed tok/s — on spawned
    virtual CPU devices the mesh arm pays emulated collectives, so this
    is an overhead *report*, not a speedup claim (the win needs real
    accelerators); and
  * greedy bit-equality of the committed stream across arms.

With ``--assert`` both arms must hold syncs/cycle == 1 in steady state
and commit bit-identical tokens — the CI smoke for mesh-sharded serving.
Emits a ``BENCH_9.json`` snapshot.

Run directly (the module spawns the virtual devices itself):

    PYTHONPATH=src python -m benchmarks.mesh_ab [--assert] [--mesh 2x4]

Output CSV: mesh_ab,<arm>,<steps>,<syncs_steady>,<cycle_ms_median>,
<tok_per_s>,<bit_identical>.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

# The mesh arm needs its devices to EXIST before jax initializes the CPU
# backend: spawn virtual devices before any jax-importing import below
# runs.  Respect a user-provided XLA_FLAGS (the CI job exports one).
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import numpy as np

from repro.core import ChainRouter, ModelPool, Placement

CHAIN = ("bench-68m", "bench-1b", "bench-7b")


def build_bench_pool(mesh=None, vocab: int = 127) -> ModelPool:
    """cycle_overhead's 3-deep dispatch-bound pool, optionally placed:
    small dense models so per-cycle wall time is orchestration (dispatch
    gaps, transfers, collectives), not FLOPs."""
    import jax
    import jax.numpy as jnp
    from repro.models import ModelConfig
    from repro.models.model import LanguageModel
    pool = ModelPool(placement=Placement.from_spec(mesh)
                     if mesh is not None else None)
    for (n, L, d, s) in [("bench-68m", 2, 32, 1), ("bench-1b", 3, 48, 2),
                         ("bench-7b", 4, 64, 3)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=vocab, dtype=jnp.float32)
        params, axes = LanguageModel(cfg).init(jax.random.PRNGKey(s))
        pool.register(cfg, params=params, param_axes=axes)
    if not pool.placement.is_trivial:
        pool.placement.auto_assign(pool.capability(), CHAIN[-1])
    return pool


def run_arm(pool, prompts, lens, max_new: int, window: int) -> Dict:
    router = ChainRouter(pool, CHAIN[-1], greedy=True, seed=0,
                         adaptive=False, fixed_chain=CHAIN,
                         fixed_window=window, fused=True,
                         profile_every=1000)
    # warmup at the SAME max_new populates every compiled shape
    router.generate(prompts, lens, max_new, request_id="warm")
    out = router.generate(prompts, lens, max_new, request_id="run")
    wall = sum(out.cycle_wall_s)

    # steady-state transfer count via a session: cycle 0 is the per-op
    # profiling cycle (intentional extra syncs), so burn it first — every
    # fused cycle after it must make exactly ONE host transfer
    sess = router.start_session(2, 96, session_id="probe")
    sess.admit(0, prompts[0, :lens[0]], 10)
    sess.admit(1, prompts[1, :lens[1]], 10)
    sess.run_cycle()
    probed, s0 = 0, router.profiler.counters["host_sync"]
    while sess.active.any() and probed < 8:
        sess.run_cycle()
        probed += 1
    syncs = (router.profiler.counters["host_sync"] - s0) / max(probed, 1)
    sess.close()

    return dict(
        generated=out.generated,
        steps=out.steps,
        syncs_steady=syncs,
        cycle_ms_median=1e3 * float(np.median(out.cycle_wall_s)),
        tok_s=out.committed_tokens / max(wall, 1e-9),
    )


def main(max_new: int = 32, batch: int = 4, window: int = 4,
         mesh: str = "2x4", do_assert: bool = False,
         out_json: str = "BENCH_9.json", print_csv: bool = True) -> Dict:
    import jax
    need = int(np.prod([int(x) for x in mesh.split("x")]))
    if jax.device_count() < need:
        # XLA_FLAGS was preset without enough devices — report, don't die
        print(f"mesh_ab,skip,need {need} devices have {jax.device_count()}"
              " (export XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{need})")
        return {}

    prompts = np.array(jax.random.randint(jax.random.PRNGKey(7),
                                          (batch, 12), 0, 127))
    lens = np.array([12, 9, 11, 7][:batch] + [10] * max(batch - 4, 0))

    report: Dict[str, Dict] = {}
    for arm, spec in (("single", None), ("mesh", mesh)):
        pool = build_bench_pool(spec)
        report[arm] = run_arm(pool, prompts, lens, max_new, window)
    ident = all(np.array_equal(a, b)
                for a, b in zip(report["single"]["generated"],
                                report["mesh"]["generated"]))
    for arm in ("single", "mesh"):
        r = report[arm]
        if print_csv:
            print(f"mesh_ab,{arm},{r['steps']},{r['syncs_steady']:.2f},"
                  f"{r['cycle_ms_median']:.2f},{r['tok_s']:.1f},"
                  f"{int(ident)}")
        r.pop("generated")
    report["bit_identical"] = ident

    if out_json:
        with open(out_json, "w") as f:
            json.dump({"bench": "mesh_ab", "mesh": mesh,
                       "max_new": max_new, "batch": batch,
                       "window": window, "arms": report}, f, indent=2)

    if do_assert:
        assert ident, "mesh arm committed different greedy tokens than " \
                      "the single-device arm"
        for arm in ("single", "mesh"):
            s = report[arm]["syncs_steady"]
            assert s == 1.0, \
                (f"{arm}: fused steady-state cycles must make exactly one "
                 f"host transfer (got {s:.2f}/cycle)")
        print("mesh_ab,assert,ok")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--assert", dest="do_assert", action="store_true",
                    help="fail unless both arms hold exactly one host "
                         "transfer per steady-state fused cycle with "
                         "bit-equal greedy output")
    ap.add_argument("--mesh", default="2x4",
                    help="mesh spec for the placed arm (default 2x4)")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--out-json", default="BENCH_9.json")
    a = ap.parse_args()
    main(max_new=a.max_new, batch=a.batch, window=a.window, mesh=a.mesh,
         do_assert=a.do_assert, out_json=a.out_json)
