"""§Perf optimizations stay semantics-preserving (EXPERIMENTS.md §Perf):
  H2  — chunkwise-parallel mLSTM ≡ recurrent form
  K4b — shard_map expert-parallel MoE ≡ dense-gather reference
  G2b — int8-KV attention ≈ full-precision (bounded error, argmax-stable)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, SSMConfig
from repro.models.model import LanguageModel


def test_chunkwise_mlstm_equals_recurrent():
    from repro.models import ssm
    for slstm_every in (0, 2):
        cfg = ModelConfig(name="t", arch_type="ssm", num_layers=4,
                          d_model=32, num_heads=2, num_kv_heads=2, d_ff=0,
                          vocab_size=61,
                          ssm=SSMConfig(slstm_every=slstm_every),
                          dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, _ = lm.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, 61)
        a = ssm.forward_train(params, cfg, toks, chunkwise=True)
        b = ssm.forward_train(params, cfg, toks, chunkwise=False)
        rel = float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(b)))
        assert rel < 1e-3, (slstm_every, rel)


def test_ep_moe_matches_dense(tmp_path):
    import subprocess, sys, textwrap
    # needs >1 device: run in a subprocess with forced host device count
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.models import ModelConfig, MoEConfig
        from repro.models import moe
        cfg = ModelConfig(name="t", arch_type="moe", num_layers=1,
                          d_model=32, num_heads=2, num_kv_heads=2, d_ff=0,
                          vocab_size=61,
                          moe=MoEConfig(num_experts=8, top_k=2, d_expert=16,
                                        capacity_factor=16.0,
                                        num_shared_experts=1, d_shared=16),
                          dtype=jnp.float32)
        p = moe.init_moe_ffn(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
        y_ref, _ = moe.moe_ffn(p, cfg, x)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            y_ep, _ = jax.jit(lambda p, x: moe.moe_ffn_ep(p, cfg, x,
                                                          mesh))(p, x)
        rel = float(jnp.max(jnp.abs(y_ep - y_ref))
                    / jnp.max(jnp.abs(y_ref)))
        assert rel < 1e-5, rel
        print("EP_OK", rel)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert "EP_OK" in r.stdout, r.stderr[-2000:]


def test_int8_kv_attention_bounded_error():
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=3,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=101, dtype=jnp.float32)
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    lm, lmq = LanguageModel(cfg), LanguageModel(cfgq)
    params, _ = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 101)
    s1, _ = lm.make_state(2, 32)
    s2, _ = lmq.make_state(2, 32)
    _, s1 = lm.prefill(params, s1, toks)
    _, s2 = lmq.prefill(params, s2, toks)
    t2 = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0, 101)
    d1, _ = lm.decode(params, s1, t2)
    d2, _ = lmq.decode(params, s2, t2)
    rel = float(jnp.max(jnp.abs(d1 - d2)) / jnp.max(jnp.abs(d1)))
    assert rel < 0.05, rel
    assert bool(jnp.all(jnp.argmax(d1, -1) == jnp.argmax(d2, -1)))


def test_int8_kv_rollback_consistent():
    """The paper's rollback machinery must hold for the quantized cache."""
    cfg = ModelConfig(name="t", arch_type="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=41, dtype=jnp.float32, kv_quant=True)
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    base = jnp.array([[5, 6, 7], [8, 9, 10]], jnp.int32)
    extra = jnp.array([[11, 12, 13, 14], [15, 16, 17, 18]], jnp.int32)
    nxt = jnp.array([[21, 22], [23, 24]], jnp.int32)
    s1, _ = lm.make_state(2, 32)
    _, s1 = lm.prefill(params, s1, base)
    _, s1 = lm.decode(params, s1, extra)
    s1 = lm.rollback(s1, jnp.array([2, 2]))
    lg1, _ = lm.decode(params, s1, nxt)
    s2, _ = lm.make_state(2, 32)
    _, s2 = lm.prefill(params, s2, base)
    _, s2 = lm.decode(params, s2, extra[:, :2])
    lg2, _ = lm.decode(params, s2, nxt)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-4, atol=1e-4)
