"""Serving engine integration: Poisson workload through SpecRouter with
metric sanity (uses a tiny random pool — fast; trained-pool behavior is
covered by benchmarks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ModelPool
from repro.data import CorpusConfig, SyntheticCorpus, make_workload
from repro.models import ModelConfig
from repro.models.model import LanguageModel
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def pool():
    p = ModelPool()
    for (n, L, d, s) in [("s", 2, 32, 1), ("t", 3, 48, 2)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=64, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


def test_engine_end_to_end(pool):
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=64))
    reqs = make_workload(corpus, "gsm8k", rate_rps=2.0, duration_s=3.0,
                         seed=2, scale=0.08, max_prompt=16, max_out=8)
    assert len(reqs) >= 2
    eng = ServingEngine(pool, "t", batch_size=3, slo_latency_s=120.0,
                        router_kwargs=dict(adaptive=True))
    m = eng.run(reqs)
    assert m.num_requests == len(reqs)
    assert m.total_tokens > 0
    assert m.goodput_tps > 0
    assert np.isfinite(m.avg_ttft_s) and m.avg_ttft_s >= 0
    assert 0.0 <= m.slo_attainment <= 1.0
    for r in reqs:
        assert r.finish_s >= r.first_token_s >= r.arrival_s
        assert 0 < r.generated <= r.max_new_tokens


def test_engine_batches_respect_arrival_order(pool):
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=64))
    reqs = make_workload(corpus, "mgsm", rate_rps=3.0, duration_s=2.0,
                         seed=5, scale=0.08, max_prompt=12, max_out=6)
    eng = ServingEngine(pool, "t", batch_size=2,
                        router_kwargs=dict(adaptive=False,
                                           fixed_chain=("t",),
                                           fixed_window=1))
    m = eng.run(reqs)
    starts = [r.start_s for r in sorted(reqs, key=lambda r: r.arrival_s)]
    assert all(b >= a - 1e-9 for a, b in zip(starts, starts[1:]))
