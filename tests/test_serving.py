"""Serving engine integration: Poisson workload through SpecRouter with
metric sanity (uses a tiny random pool — fast; trained-pool behavior is
covered by benchmarks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ModelPool
from repro.data import CorpusConfig, SyntheticCorpus, make_workload
from repro.models import ModelConfig
from repro.models.model import LanguageModel
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def pool():
    p = ModelPool()
    for (n, L, d, s) in [("s", 2, 32, 1), ("t", 3, 48, 2)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=64, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


def test_engine_end_to_end(pool):
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=64))
    reqs = make_workload(corpus, "gsm8k", rate_rps=2.0, duration_s=3.0,
                         seed=2, scale=0.08, max_prompt=16, max_out=8)
    assert len(reqs) >= 2
    eng = ServingEngine(pool, "t", batch_size=3, slo_latency_s=120.0,
                        router_kwargs=dict(adaptive=True))
    m = eng.run(reqs)
    assert m.num_requests == len(reqs)
    assert m.total_tokens > 0
    assert m.goodput_tps > 0
    assert np.isfinite(m.avg_ttft_s) and m.avg_ttft_s >= 0
    assert 0.0 <= m.slo_attainment <= 1.0
    for r in reqs:
        assert r.finish_s >= r.first_token_s >= r.arrival_s
        assert 0 < r.generated <= r.max_new_tokens


def test_metrics_nan_safe_on_degenerate_runs(pool):
    """Empty done-set and zero makespan must yield NaN-safe metrics, not
    ZeroDivisionError / ValueError on max() of an empty sequence."""
    from repro.data.workload import Request
    eng = ServingEngine(pool, "t")
    m0 = eng._metrics([], [])
    assert m0.num_requests == 0 and m0.total_tokens == 0
    assert np.isnan(m0.goodput_tps) and np.isnan(m0.avg_ttft_s)

    # single instant request: finish == arrival -> makespan == 0
    r = Request("r0", 1.0, np.array([1, 2]), 4, "synthetic",
                start_s=1.0, first_token_s=1.0, finish_s=1.0, generated=4)
    m1 = eng._metrics([r], [1.0])
    assert m1.makespan_s == 0.0
    assert np.isnan(m1.goodput_tps) and np.isnan(m1.request_throughput_rps)
    assert m1.num_requests == 1 and m1.total_tokens == 4
    assert np.isfinite(m1.avg_ttft_s)


def test_termination_scans_only_new_commits(pool):
    """The EOS scan is bounded to this cycle's commits: a token equal to
    EOS sitting in the already-scanned region is never re-examined (and
    the full-scan fallback without scan_from still finds it)."""
    from repro.core import ChainRouter
    router = ChainRouter(pool, "t", eos_token=9)
    seq = np.zeros((1, 32), np.int32)
    seq[0, :8] = [1, 2, 3, 9, 5, 6, 7, 8]     # "EOS" at committed pos 3
    seq_len = np.array([8], np.int64)
    prompt = np.array([2], np.int64)
    budget = np.array([20], np.int64)
    active = np.array([True])
    # scan_from = 7: only the last commit (token 8) is examined
    router._apply_termination(seq, seq_len, prompt, budget, active,
                              scan_from=np.array([7]))
    assert active[0] and seq_len[0] == 8
    # fallback full scan (no scan_from) finds the stale EOS
    router._apply_termination(seq, seq_len, prompt, budget, active)
    assert not active[0] and seq_len[0] == 2 + 2


def test_engine_batches_respect_arrival_order(pool):
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=64))
    reqs = make_workload(corpus, "mgsm", rate_rps=3.0, duration_s=2.0,
                         seed=5, scale=0.08, max_prompt=12, max_out=6)
    eng = ServingEngine(pool, "t", batch_size=2,
                        router_kwargs=dict(adaptive=False,
                                           fixed_chain=("t",),
                                           fixed_window=1))
    m = eng.run(reqs)
    starts = [r.start_s for r in sorted(reqs, key=lambda r: r.arrival_s)]
    assert all(b >= a - 1e-9 for a, b in zip(starts, starts[1:]))
