"""ModelChainScheduler (Alg. 1, Eq. 7) and similarity/EMA units."""


from repro.core import (EMA, ModelChainScheduler, PerformanceProfiler,
                        SimilarityStore, acceptance_from_sim,
                        expected_accepted)


def test_ema_formula():
    e = EMA(alpha=0.3)
    e.update(10.0)
    assert e.get() == 10.0
    e.update(20.0)
    assert abs(e.get() - (0.3 * 20 + 0.7 * 10)) < 1e-9


def test_simscore_eq6():
    s = SimilarityStore(alpha=0.5)
    s.update("a", "b", 0.4)
    assert abs(s.sim_score("a", "b") - 0.6) < 1e-9
    s.update("b", "a", 0.2)            # symmetric key
    assert abs(s.sim_score("a", "b") - (1 - (0.5 * 0.2 + 0.5 * 0.4))) < 1e-9
    assert s.sim_score("a", "a") == 1.0
    # unobserved pairs default pessimistic
    assert s.sim_score("a", "zzz") <= 0.2


def test_acceptance_identity_mapping():
    assert abs(acceptance_from_sim(0.7) - 0.7) < 1e-9
    # calibrated sigmoid stays monotone
    xs = [acceptance_from_sim(x, 1.5, 0.3) for x in (0.2, 0.5, 0.8)]
    assert xs[0] < xs[1] < xs[2]


def test_expected_accepted_geometric():
    # Σ_{k=1..w} α^k
    for a, w in [(0.5, 4), (0.9, 6), (0.0, 3), (1.0, 5)]:
        want = sum(a ** k for k in range(1, w + 1))
        assert abs(expected_accepted(a, w) - want) < 1e-9


def _mk_sched(times, sims, target="t"):
    prof = PerformanceProfiler()
    for m, v in times.items():
        prof.record("decode1", m, v)
    store = SimilarityStore()
    for (a, b), s in sims.items():
        store.update(a, b, 1.0 - s)
    cap = {m: 10.0 ** i for i, m in enumerate(sorted(times))}
    return ModelChainScheduler(list(times), target, prof, store, cap,
                               windows=(4,), verify_overhead=0.0,
                               switch_penalty_steps=1e9)


def test_two_level_matches_eq4():
    """For a 2-model chain with ν=0 the predictor reduces to the paper's
    Eq. 4 shape: T_eff = (W·T_q + T_p) / (Σ α^k + 1)."""
    sched = _mk_sched({"q": 0.01, "t": 0.1}, {("q", "t"): 0.8})
    t = sched.predict_t_eff(("q", "t"), 4)
    acc = sum(0.8 ** k for k in range(1, 5))
    want = (4 * 0.01 + 0.1) / (acc + 1)
    assert abs(t - want) / want < 1e-6


def test_scheduler_picks_analytic_argmin():
    """With a fast, similar draft the chain must beat target-only; with a
    dissimilar draft, target-only must win."""
    sched = _mk_sched({"d": 0.005, "t": 0.1}, {("d", "t"): 0.9})
    best = sched.get_optimal_chain()
    assert best.chain == ("d", "t")

    sched2 = _mk_sched({"d": 0.005, "t": 0.1}, {("d", "t"): 0.01})
    best2 = sched2.get_optimal_chain()
    assert best2.chain == ("t",)


def test_three_level_beats_two_when_intermediate_helps():
    """Classic multi-level setup: cheap draft, mid verifier with high
    mutual similarity both ways, expensive target."""
    times = {"a": 0.002, "m": 0.02, "t": 0.4}
    sims = {("a", "m"): 0.9, ("a", "t"): 0.35, ("m", "t"): 0.9}
    sched = _mk_sched(times, sims)
    t3 = sched.predict_t_eff(("a", "m", "t"), 4)
    t2 = sched.predict_t_eff(("a", "t"), 4)
    assert t3 < t2
    assert sched.get_optimal_chain().chain == ("a", "m", "t")


def test_candidate_chains_end_with_target():
    sched = _mk_sched({"a": 1, "b": 2, "t": 3}, {})
    for c in sched.candidate_chains():
        assert c[-1] == "t"
    assert ("t",) in sched.candidate_chains()


def test_memoized_until_inputs_drift():
    """With reschedule_every=1 the full Eq. 7 sweep used to run every
    cycle; now it reuses the previous argmin until a profiler/similarity
    EMA moves by more than reuse_rtol."""
    prof = PerformanceProfiler()
    prof.record("decode1", "d", 0.001)
    prof.record("decode1", "t", 0.1)
    store = SimilarityStore()
    store.update("d", "t", 0.1)
    sched = ModelChainScheduler(["d", "t"], "t", prof, store,
                                {"d": 1, "t": 100})
    c1 = sched.get_optimal_chain()
    c2 = sched.get_optimal_chain()
    assert sched.eval_count == 1 and sched.reuse_count == 1
    assert c2 is c1
    # sub-threshold EMA drift keeps the memo
    prof.record("decode1", "d", 0.001 * 1.0001)
    assert sched.get_optimal_chain() is c1
    assert sched.eval_count == 1
    # a real change invalidates it
    for _ in range(8):
        prof.record("decode1", "t", 0.4)
    sched.get_optimal_chain()
    assert sched.eval_count == 2
    # a NEW observation key (first verify EMA) also invalidates
    prof.record("verify", "t", 0.2, block=5)
    sched.get_optimal_chain()
    assert sched.eval_count == 3


def test_memoization_disabled_with_zero_rtol():
    prof = PerformanceProfiler()
    prof.record("decode1", "d", 0.001)
    prof.record("decode1", "t", 0.1)
    sched = ModelChainScheduler(["d", "t"], "t", prof, SimilarityStore(),
                                {"d": 1, "t": 100}, reuse_rtol=0.0)
    sched.get_optimal_chain()
    sched.get_optimal_chain()
    assert sched.eval_count == 2 and sched.reuse_count == 0


def test_window_is_searched():
    prof = PerformanceProfiler()
    prof.record("decode1", "d", 0.001)
    prof.record("decode1", "t", 0.1)
    store = SimilarityStore()
    store.update("d", "t", 0.05)   # very similar -> bigger window pays
    sched = ModelChainScheduler(["d", "t"], "t", prof, store,
                                {"d": 1, "t": 100}, windows=(1, 8),
                                verify_overhead=0.0)
    assert sched.get_optimal_chain().window == 8
