"""Verification-rule correctness (paper §2.2): greedy exactness and
distribution-preservation of rejection sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import verification as ver


def test_greedy_accept_prefix():
    V = 11
    logits = jnp.full((1, 4, V), -10.0)
    # verifier argmaxes: 3, 5, 7 (then bonus position argmax 2)
    for i, t in enumerate([3, 5, 7, 2]):
        logits = logits.at[0, i, t].set(10.0)
    cand = jnp.array([[3, 5, 9]])          # mismatch at position 2
    res = ver.verify_greedy(cand, logits)
    assert int(res.num_accepted[0]) == 2
    assert int(res.next_token[0]) == 7     # correction = argmax at reject
    assert int(res.rollback[0]) == 1

    cand2 = jnp.array([[3, 5, 7]])         # all accepted -> bonus
    res2 = ver.verify_greedy(cand2, logits)
    assert int(res2.num_accepted[0]) == 3
    assert int(res2.next_token[0]) == 2
    assert int(res2.rollback[0]) == 0


def test_greedy_inactive_row_noop():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 7))
    cand = jnp.array([[1, 2, 3], [4, 5, 6]])
    res = ver.verify_greedy(cand, logits, active=jnp.array([True, False]))
    assert int(res.num_accepted[1]) == 0
    assert int(res.rollback[1]) == 0       # nothing valid appended


def test_splice_candidates():
    cand = jnp.array([[10, 11, 12]])
    res = ver.VerifyResult(
        num_accepted=jnp.array([1]), next_token=jnp.array([99]),
        next_probs=jnp.ones((1, 7)) / 7, rollback=jnp.array([2]),
        dtv=jnp.zeros((1,)))
    nxt, _, vlen = ver.splice_candidates(cand, None, res)
    np.testing.assert_array_equal(nxt[0], [10, 99, 99, 99])
    assert int(vlen[0]) == 2


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_rejection_sampling_unbiased(seed):
    """Core SD theorem: verify(q-samples) ~ p exactly.  Tiny vocab, many
    trials, chi-square-ish tolerance."""
    V, N = 5, 4000
    kp, kq, kd, kv = jax.random.split(jax.random.PRNGKey(seed), 4)
    p_logits = jax.random.normal(kp, (V,)) * 1.5
    q_logits = jax.random.normal(kq, (V,)) * 1.5
    p = jax.nn.softmax(p_logits)
    q = jax.nn.softmax(q_logits)

    # draft N tokens from q, verify each (window=1) against p
    draft = jax.random.categorical(kd, jnp.broadcast_to(q_logits, (N, V)))
    cand = draft[:, None]                                    # (N, 1)
    vlogits = jnp.broadcast_to(p_logits, (N, 2, V))          # l_0 + bonus
    cprobs = jnp.broadcast_to(q, (N, 1, V))
    res = ver.verify_sampling(cand, vlogits, cprobs, kv)
    # committed token per row: accepted draft or the resampled correction
    committed = jnp.where(res.num_accepted == 1, cand[:, 0], res.next_token)
    freq = np.bincount(np.asarray(committed), minlength=V) / N
    np.testing.assert_allclose(freq, np.asarray(p), atol=0.035)


def test_sampling_valid_len_bounds_acceptance():
    V = 7
    key = jax.random.PRNGKey(0)
    cand = jnp.array([[1, 2, 3, 4]])
    # verifier fully agrees with producer -> everything would be accepted
    probs = jnp.ones((1, 4, V)) / V
    logits = jnp.zeros((1, 5, V))
    res = ver.verify_sampling(cand, logits, probs, key,
                              valid_len=jnp.array([2]))
    assert int(res.num_accepted[0]) <= 2
