"""Slot-level continuous batching: admission into freed slots mid-flight,
queueing-delay billing, retirement semantics, and the head-of-line-blocking
A/B against the legacy batch-formation engine (tiny random pool — fast)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool
from repro.data.workload import Request
from repro.models import ModelConfig
from repro.models.model import LanguageModel
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def pool():
    p = ModelPool()
    for (n, L, d, s) in [("s", 2, 32, 1), ("t", 3, 48, 2)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=64, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


def _req(i, arrival, plen, budget, rng):
    return Request(request_id=f"r{i}", arrival_s=arrival,
                   prompt=rng.integers(1, 64, size=plen).astype(np.int64),
                   max_new_tokens=budget, dataset="synthetic")


def _hol_workload():
    """One long request, then a burst of short ones right behind it.
    Uniform prompt length keeps every jit shape identical across engines
    so compile time cannot skew the simulated clock."""
    rng = np.random.default_rng(0)
    reqs = [_req(0, 0.0, 8, 32, rng)]
    reqs += [_req(i, 0.01 * i, 8, 4, rng) for i in range(1, 6)]
    return reqs


# ---------------------------------------------------------------------------
# session-level semantics
# ---------------------------------------------------------------------------
def test_mid_flight_admission_fills_freed_slot(pool):
    """A request admitted after another retires reuses its slot row and
    decodes the same stream as a fresh target-only reference."""
    rng = np.random.default_rng(3)
    router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("s", "t"),
                        fixed_window=3)
    sess = router.start_session(2, 128, session_id="sess")
    pa = rng.integers(1, 64, size=6).astype(np.int64)
    pb = rng.integers(1, 64, size=8).astype(np.int64)
    pc = rng.integers(1, 64, size=7).astype(np.int64)
    sess.admit(0, pa, 4)
    sess.admit(1, pb, 12)
    while sess.active[0]:
        sess.run_cycle()
    out_a = sess.retire(0)
    assert len(out_a) == 4 and not sess.occupied[0]
    assert sess.occupied[1]              # slot 1 kept running

    # mid-flight admission into the freed slot, while slot 1 is live
    sess.admit(0, pc, 6)
    assert sess.occupied[0] and sess.active[0]
    while sess.active.any():
        sess.run_cycle()
    out_c = sess.retire(0)
    out_b = sess.retire(1)
    sess.close()
    assert len(out_c) == 6 and len(out_b) == 12

    # greedy equivalence: the admitted-into-dirty-slot stream must be
    # bit-identical to a fresh single-row target-only decode
    ref_router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("t",),
                             fixed_window=1)
    ref = ref_router.generate(pc[None, :], np.array([7]), 6,
                              request_id="ref")
    np.testing.assert_array_equal(out_c, ref.generated[0])


def test_retired_slot_stops_billing(pool):
    """Cycles run after a slot retires leave its request's finish time and
    token count untouched."""
    rng = np.random.default_rng(4)
    reqs = [_req(0, 0.0, 6, 3, rng), _req(1, 0.0, 6, 20, rng)]
    eng = ServingEngine(pool, "t", batch_size=2, continuous=True,
                        router_kwargs=dict(adaptive=False,
                                           fixed_chain=("t",),
                                           fixed_window=1))
    eng.run(reqs)
    short, long = reqs
    # the short request finished well before the long one, even though the
    # engine kept cycling the shared slot pool afterwards
    assert short.finish_s < long.finish_s
    assert short.generated == 3
    assert long.generated == 20
    assert short.latency < long.latency


def test_ttft_includes_queueing_delay(pool):
    """A request that arrives while all slots are busy must bill its wait
    for a free slot into TTFT: first_token - arrival >= start - arrival > 0
    and start_s (admission) is after the blocking work."""
    rng = np.random.default_rng(5)
    # 1 slot: r1 arrives immediately but must wait for r0 to finish
    reqs = [_req(0, 0.0, 8, 16, rng), _req(1, 0.01, 6, 4, rng)]
    eng = ServingEngine(pool, "t", batch_size=1, continuous=True,
                        router_kwargs=dict(adaptive=False,
                                           fixed_chain=("t",),
                                           fixed_window=1))
    eng.run(reqs)
    r0, r1 = reqs
    assert r1.start_s >= r0.finish_s - 1e-9       # waited for the slot
    assert r1.queue_delay > 0
    assert r1.ttft >= r1.queue_delay              # queueing billed to TTFT
    assert r1.first_token_s > r1.start_s


def test_continuous_matches_legacy_on_single_batch(pool):
    """When every request fits one batch/slot-pool, both engines serve the
    same token streams: identical counts, budgets, and metric structure."""
    rng = np.random.default_rng(6)
    reqs_c = [_req(i, 0.001 * i, 6 + i, 5 + i, rng) for i in range(3)]
    reqs_l = [Request(r.request_id, r.arrival_s, r.prompt.copy(),
                      r.max_new_tokens, r.dataset) for r in reqs_c]
    kw = dict(adaptive=False, fixed_chain=("s", "t"), fixed_window=3)
    mc = ServingEngine(pool, "t", batch_size=3, continuous=True,
                       router_kwargs=kw).run(reqs_c)
    ml = ServingEngine(pool, "t", batch_size=3, continuous=False,
                       router_kwargs=kw).run(reqs_l)
    assert mc.num_requests == ml.num_requests == 3
    assert mc.total_tokens == ml.total_tokens
    for rc, rl in zip(reqs_c, reqs_l):
        assert rc.generated == rl.generated
        assert rc.finish_s >= rc.first_token_s >= rc.arrival_s
    for m in (mc, ml):
        assert np.isfinite(m.avg_ttft_s) and m.avg_ttft_s >= 0
        assert m.goodput_tps > 0


# ---------------------------------------------------------------------------
# acceptance: head-of-line blocking A/B
# ---------------------------------------------------------------------------
@pytest.mark.slow   # three full engine runs per arm (jit warm + measure)
def test_p95_ttft_beats_legacy_under_hol_blocking(pool):
    """One long request ahead of several short ones: the continuous engine
    must deliver strictly lower p95 TTFT than stop-the-world batch
    formation (the legacy engine parks every short request behind the
    long one's generate-to-completion)."""
    kw = dict(adaptive=False, fixed_chain=("t",), fixed_window=1)
    rng = np.random.default_rng(1)

    def measure(continuous):
        eng = ServingEngine(pool, "t", batch_size=3, batch_wait_s=0.05,
                            continuous=continuous, router_kwargs=kw)
        # warm every jitted shape (prefill/insert/cycle, both the long-
        # and short-budget state sizes) so compile time is not billed
        # into either engine's measured clock
        eng.run([_req(100, 0.0, 8, 32, rng)]
                + [_req(101 + i, 0.0, 8, 4, rng) for i in range(2)])
        eng.run([_req(103 + i, 0.0, 8, 4, rng) for i in range(3)])
        reqs = _hol_workload()
        return eng.run(reqs)

    mc = measure(True)
    ml = measure(False)
    assert mc.p95_ttft_s < ml.p95_ttft_s
