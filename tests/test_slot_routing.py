"""Per-slot chain routing with lazy chain membership: O(chain) admission
(pinned prefill/insert counters, zero footprint in non-chain models),
bit-exact grouped sub-cycles for slots on different chains, clean
rejection of over-long prompts, the vectorized gap-prefix fast path, and
the profiler's bounded trace ring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool, PerformanceProfiler
from repro.core.scheduler import ModelChainScheduler
from repro.core.similarity import SimilarityStore
from repro.core.state_manager import StateManager
from repro.models import ModelConfig
from repro.models.model import LanguageModel


@pytest.fixture(scope="module")
def pool():
    """Three models: s (draft), t (target), u (pool member that no chain
    ever uses — the lazy-membership probe)."""
    p = ModelPool()
    for (n, L, d, s) in [("s", 2, 32, 1), ("t", 3, 48, 2), ("u", 2, 32, 9)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=64, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


def _target_only(pool, prompt, budget, rid):
    r = ChainRouter(pool, "t", adaptive=False, fixed_chain=("t",),
                    fixed_window=1)
    return r.generate(prompt[None, :], np.array([len(prompt)]), budget,
                      request_id=rid).generated[0]


# ---------------------------------------------------------------------------
# O(chain) admission: pinned counters + zero non-chain footprint
# ---------------------------------------------------------------------------
def test_admission_touches_only_chain_members(pool):
    """Admission prefill work is O(chain), not O(pool): with the chain
    fixed to (s, t), model u is never prefilled, never inserted into,
    never holds a state — and the s/t counters are pinned to exactly one
    state-creating prefill plus one per-row insert."""
    rng = np.random.default_rng(0)
    router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("s", "t"),
                         fixed_window=3)
    sess = router.start_session(2, 128, session_id="oc")
    sess.admit(0, rng.integers(1, 64, size=6).astype(np.int64), 4)
    sess.admit(1, rng.integers(1, 64, size=7).astype(np.int64), 4)
    c = router.profiler.counters
    # chain members: first admit creates the state (one batched prefill),
    # second admit is a single row insert — pinned exactly
    for m in ("s", "t"):
        assert c.get(f"prefill.{m}.calls", 0) == 1
        assert c.get(f"insert.{m}.calls", 0) == 1
        assert c.get(f"admit.{m}", 0) == 2
    # the non-chain pool member: zero ops, zero state, zero rows/blocks
    assert not any(k for k in c if ".u" in k or k.endswith(".u")
                   or k.startswith(("prefill.u", "insert.u", "admit.u")))
    sid_u = StateManager.key("u", "oc")
    assert not router.states.exists(sid_u)
    for slot in (0, 1):
        assert router.states.row_footprint(sid_u, slot) == 0
    # a slot routed target-only holds no rows in the draft either
    while sess.active.any():
        sess.run_cycle()
    sess.retire(0)
    sess.retire(1)
    # retirement freed the member rows; the emptied states were released
    assert not router.states.exists(StateManager.key("s", "oc"))
    sess.close()


def test_per_slot_chain_leaves_other_models_empty(pool):
    """A slot admitted with an explicit target-only chain must hold zero
    rows in the draft even while another slot routes through it."""
    rng = np.random.default_rng(1)
    router = ChainRouter(pool, "t", adaptive=False)
    sess = router.start_session(2, 128, session_id="pf")
    sess.admit(0, rng.integers(1, 64, size=6).astype(np.int64), 4,
               chain=("s", "t"), window=3)
    sess.admit(1, rng.integers(1, 64, size=6).astype(np.int64), 4,
               chain=("t",))
    sid_s = StateManager.key("s", "pf")
    assert router.states.row_footprint(sid_s, 0) > 0
    assert router.states.row_footprint(sid_s, 1) == 0
    while sess.active.any():
        sess.run_cycle()
    assert router.states.row_footprint(sid_s, 1) == 0
    sess.close()


# ---------------------------------------------------------------------------
# grouped sub-cycles: bit-exactness with slots on DIFFERENT chains
# ---------------------------------------------------------------------------
def test_two_slots_different_chains_bit_exact(pool):
    """Two live slots assigned different chains run as separate masked
    sub-cycles per run_cycle; each stream must equal a fresh target-only
    decode (grouping must not leak state across groups)."""
    rng = np.random.default_rng(2)
    pa = rng.integers(1, 64, size=6).astype(np.int64)
    pb = rng.integers(1, 64, size=8).astype(np.int64)
    router = ChainRouter(pool, "t", adaptive=False)
    sess = router.start_session(2, 128, session_id="2c")
    sess.admit(0, pa, 7, chain=("s", "t"), window=3)
    sess.admit(1, pb, 9, chain=("t",))
    saw_two_groups = False
    while sess.active.any():
        rep = sess.run_cycle()
        if len(rep.groups) == 2:
            saw_two_groups = True
    assert saw_two_groups, "different chains should form distinct groups"
    out_a, out_b = sess.retire(0), sess.retire(1)
    sess.close()
    np.testing.assert_array_equal(out_a, _target_only(pool, pa, 7, "ra"))
    np.testing.assert_array_equal(out_b, _target_only(pool, pb, 9, "rb"))


def test_mid_flight_chain_join_catches_up(pool):
    """A model joining a slot's chain after admission catches up through
    the insert path and the stream stays bit-exact: admit target-only,
    then re-pin the slot to (s, t) mid-generation."""
    rng = np.random.default_rng(4)
    pa = rng.integers(1, 64, size=6).astype(np.int64)
    router = ChainRouter(pool, "t", adaptive=False)
    sess = router.start_session(1, 128, session_id="join")
    sess.admit(0, pa, 8, chain=("t",))
    sess.run_cycle()
    sess.run_cycle()
    assert not router.states.exists(StateManager.key("s", "join"))
    # re-pin mid-flight: the draft materializes lazily at the next cycle
    from repro.core.scheduler import ChainChoice
    sess._slot_choice[0] = ChainChoice(("s", "t"), 3, 0.0)
    sess._forced[0] = True
    while sess.active.any():
        sess.run_cycle()
    assert router.profiler.counters.get("admit.s", 0) >= 1
    out = sess.retire(0)
    sess.close()
    np.testing.assert_array_equal(out, _target_only(pool, pa, 8, "rj"))


# ---------------------------------------------------------------------------
# admission validation (satellite bugfix)
# ---------------------------------------------------------------------------
def test_unknown_chain_model_rejected_before_mutation(pool):
    """An explicit chain naming a model outside the pool must be
    rejected up front — a KeyError mid-admission would leak the slot."""
    rng = np.random.default_rng(7)
    router = ChainRouter(pool, "t", adaptive=False)
    sess = router.start_session(1, 64, session_id="uk")
    with pytest.raises(ValueError):
        sess.admit(0, rng.integers(1, 64, size=6).astype(np.int64), 4,
                   chain=("typo", "t"))
    assert not sess.occupied[0] and not sess.active[0]
    sess.admit(0, rng.integers(1, 64, size=6).astype(np.int64), 4,
               chain=("t",))
    assert sess.occupied[0]
    sess.close()


def test_chain_history_is_bounded(pool):
    router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("t",),
                         fixed_window=1)
    sess = router.start_session(1, 64, session_id="ch")
    assert sess.chain_history.maxlen is not None


def test_overlong_prompt_rejected_before_mutation(pool):
    """A prompt that cannot fit the slot row raises ValueError up front
    and leaves the session consistent: the slot stays free and a valid
    admit afterwards succeeds."""
    rng = np.random.default_rng(5)
    router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("t",),
                         fixed_window=1)
    sess = router.start_session(1, 64, session_id="cap")
    with pytest.raises(ValueError):
        sess.admit(0, rng.integers(1, 64, size=70).astype(np.int64), 4)
    assert not sess.occupied[0] and not sess.active[0]
    assert sess.seq_len[0] == 0
    with pytest.raises(ValueError):   # prompt fits, prompt+budget doesn't
        sess.admit(0, rng.integers(1, 64, size=30).astype(np.int64), 60)
    assert not sess.occupied[0]
    sess.admit(0, rng.integers(1, 64, size=8).astype(np.int64), 4)
    assert sess.occupied[0] and sess.active[0]
    while sess.active.any():
        sess.run_cycle()
    assert len(sess.retire(0)) == 4
    sess.close()


# ---------------------------------------------------------------------------
# vectorized gap prefix == per-row loop reference
# ---------------------------------------------------------------------------
def _gap_prefix_loop_ref(seq, seq_len, cache_len, active, gap, w):
    B = seq.shape[0]
    prefix = np.zeros((B, w), np.int32)
    pvalid = np.zeros((B, w), bool)
    for b in range(B):
        g = int(gap[b])
        if g > 0:
            prefix[b, w - 1 - g:w - 1] = seq[b, cache_len[b]:cache_len[b] + g]
            pvalid[b, w - 1 - g:w - 1] = True
        if active[b]:
            prefix[b, -1] = seq[b, seq_len[b] - 1]
        pvalid[b, -1] = bool(active[b])
    return prefix, pvalid


def test_gap_prefix_vectorization_matches_loop(pool):
    """The numpy fancy-indexed _gap_prefix must reproduce the per-row
    loop exactly on random gaps, inactive rows, and bucket widths."""
    router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("s", "t"),
                         fixed_window=3)
    rng = np.random.default_rng(6)
    B = 5
    sess = router.start_session(B, 64, session_id="gp")
    for s in range(B):
        sess.admit(s, rng.integers(1, 64, size=int(rng.integers(2, 9))
                                   ).astype(np.int64), 4)
    sess.run_cycle()
    sid = StateManager.key("s", "gp")
    for trial in range(20):
        active = rng.random(B) < 0.7
        cache_len = router.states.lengths(sid)
        pfx, pval, gap = router._gap_prefix("s", "gp", sess.seq,
                                            sess.seq_len, active)
        assert pfx is not None
        ref_p, ref_v = _gap_prefix_loop_ref(sess.seq, sess.seq_len,
                                            cache_len, active, gap,
                                            pfx.shape[1])
        # invalid slots may hold different padding; compare only where
        # the mask exposes them, plus the masks themselves
        np.testing.assert_array_equal(pval, ref_v)
        np.testing.assert_array_equal(np.where(pval, pfx, 0),
                                      np.where(ref_v, ref_p, 0))
        sess.run_cycle()
    sess.close()


# ---------------------------------------------------------------------------
# per-slot scheduler view (pure python, fast)
# ---------------------------------------------------------------------------
def test_slot_view_overrides_global_prior():
    """Two slots with opposite acceptance evidence must route onto
    different chains from the same scheduler."""
    prof = PerformanceProfiler()
    prof.record("decode1", "d", 0.005)
    prof.record("decode1", "t", 0.1)
    store = SimilarityStore()
    store.update("d", "t", 0.5)           # middling global prior
    sched = ModelChainScheduler(["d", "t"], "t", prof, store,
                                {"d": 1, "t": 100}, windows=(4,),
                                switch_penalty_steps=1e9)
    for _ in range(6):
        sched.observe_slot("s0", "d", "t", 0.02)   # easy request
        sched.observe_slot("s1", "d", "t", 0.98)   # hard request
    easy = sched.get_optimal_chain(slot="s0")
    hard = sched.get_optimal_chain(slot="s1")
    assert easy.chain == ("d", "t")
    assert hard.chain == ("t",)
    # slot memos are independent: re-query reuses without re-sweeping
    evals = sched.eval_count
    assert sched.get_optimal_chain(slot="s0") is easy
    assert sched.get_optimal_chain(slot="s1") is hard
    assert sched.eval_count == evals
    # released slots fall back to the shared prior
    sched.release_slot("s1")
    fresh = sched.get_optimal_chain(slot="s1")
    glob = sched.get_optimal_chain()
    assert fresh.chain == glob.chain


def test_unobserved_pairs_use_exploration_default():
    """Never-observed pairs must stay schedulable (lazy membership means
    nothing else will ever measure them): with a fast draft the explore
    default admits the chain; observed-bad evidence kills it."""
    prof = PerformanceProfiler()
    prof.record("decode1", "d", 0.001)
    prof.record("decode1", "t", 0.1)
    sched = ModelChainScheduler(["d", "t"], "t", prof, SimilarityStore(),
                                {"d": 1, "t": 100}, windows=(4,),
                                switch_penalty_steps=1e9)
    assert sched.get_optimal_chain().chain == ("d", "t")
    for _ in range(8):
        sched.sims.update("d", "t", 0.99)
    assert sched.get_optimal_chain().chain == ("t",)


# ---------------------------------------------------------------------------
# profiler trace ring (satellite bugfix)
# ---------------------------------------------------------------------------
def test_profiler_trace_is_bounded():
    prof = PerformanceProfiler(trace_cap=16)
    for i in range(100):
        prof.record("decode1", "m", 0.001 * i)
    assert len(prof.trace) == 16
    # the ring keeps the MOST RECENT records
    assert prof.trace[-1].wall_s == pytest.approx(0.099)
    assert prof.trace[0].wall_s == pytest.approx(0.084)
    # EMAs/counters still see every observation
    assert prof.counters["decode1.m.calls"] == 100
    # unbounded opt-in for offline analyses
    prof2 = PerformanceProfiler(trace_cap=None)
    for i in range(100):
        prof2.record("decode1", "m", 0.001)
    assert len(prof2.trace) == 100


def test_serving_engine_defaults_to_bounded_trace(pool):
    from repro.serving import ServingEngine
    eng = ServingEngine(pool, "t")
    assert eng._router.profiler.trace.maxlen is not None
    assert eng._router.profiler.trace.maxlen <= 4096
