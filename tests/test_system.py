"""End-to-end behaviour tests for the SpecRouter system: pool -> adaptive
multi-level speculative generation -> paper §5 guarantees, all layers
(scheduler, executor, state manager, verification) exercised together."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool
from repro.models import ModelConfig
from repro.models.model import LanguageModel

pytestmark = pytest.mark.slow   # end-to-end adaptive generation, ~80 s on CPU


@pytest.fixture(scope="module")
def system():
    pool = ModelPool()
    for (n, L, d, s) in [("sys-draft", 2, 32, 1), ("sys-target", 4, 64, 3)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=61, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        pool.register(cfg, params=params, param_axes=axes)
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                         (2, 6), 0, 61))
    plens = np.array([6, 4])
    return pool, prompt, plens


def test_system_generates_and_matches_target(system):
    pool, prompt, plens = system
    ref = ChainRouter(pool, "sys-target", greedy=True, adaptive=False,
                      fixed_chain=("sys-target",), fixed_window=1
                      ).generate(prompt, plens, 10, request_id="r")
    out = ChainRouter(pool, "sys-target", greedy=True, adaptive=True
                      ).generate(prompt, plens, 10, request_id="a")
    assert out.committed_tokens == sum(len(g) for g in out.generated)
    for b in range(2):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])


def test_system_feedback_loop_populates_metrics(system):
    pool, prompt, plens = system
    r = ChainRouter(pool, "sys-target", greedy=True, adaptive=True)
    r.generate(prompt, plens, 8, request_id="m")
    # the profiler/similarity feedback loop (paper §4.6) must be live
    assert r.profiler.decode_time("sys-target", -1) > 0
    assert r.sims.observed("sys-draft", "sys-target")
    choice = r.scheduler.get_optimal_chain()
    assert choice.chain[-1] == "sys-target"
    assert choice.predicted_t_eff > 0
