"""Mesh-sharded serving: the Placement abstraction (per-pool-member mesh
slices + NamedSharding trees) threaded through ModelPool, StateManager,
Executor, scheduler, and engine.

Pinned here:
  * Placement unit semantics — spec parsing, kinds, qualified profiling
    keys, trivial degeneration;
  * EXACT memory accounting — repeated ModelPool load/unload cycles
    return per-device usage to zero (the old DeviceManager recomputed and
    clamped; the Placement reverses the precise charge it took);
  * placement-keyed scheduler T_i — the same model on a different slice
    reads a different EMA;
  * the serving engine's ``mesh=`` knob;
  * the 1x1-mesh bit-exactness anchor (full placement path active,
    byte-identical lowering);
  * the 8-virtual-device suite (gated on spawned device count): sharded
    prefill/insert/retire, paged rollback, tree resolve, one host
    transfer per fused cycle, and speclint conformance on placed pools.

Run the 8-device half with:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        pytest -m mesh tests/
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool, Placement, parse_mesh
from repro.core.placement import KINDS
from repro.core.profiler import PerformanceProfiler
from repro.core.scheduler import ModelChainScheduler
from repro.core.similarity import SimilarityStore
from repro.models import ModelConfig
from repro.models.model import LanguageModel

mesh8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 spawned devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def tiny_cfg(name, L=2, d=32, heads=4, kv=2, vocab=61):
    return ModelConfig(name=name, arch_type="dense", num_layers=L,
                       d_model=d, num_heads=heads, num_kv_heads=kv,
                       d_ff=2 * d, vocab_size=vocab, dtype=jnp.float32)


def build_pool(mesh=None, lazy=False):
    p = ModelPool(placement=Placement.from_spec(mesh)
                  if mesh is not None else None)
    for (n, L, d, s) in [("m68", 2, 32, 1), ("m7b", 4, 64, 3)]:
        cfg = tiny_cfg(n, L=L, d=d)
        lm = LanguageModel(cfg)
        if lazy:
            def init_fn(lm=lm, s=s):
                return lm.init(jax.random.PRNGKey(s))
            p.register(cfg, init_fn=init_fn)
        else:
            params, axes = lm.init(jax.random.PRNGKey(s))
            p.register(cfg, params=params, param_axes=axes)
    if not p.placement.is_trivial:
        p.placement.auto_assign(p.capability(), "m7b")
    return p


# ---------------------------------------------------------------------------
# Placement unit semantics (fast, no jit)
# ---------------------------------------------------------------------------
def test_parse_mesh_specs():
    m = parse_mesh("1x1")
    assert m.axis_names == ("data", "model") and m.size == 1
    assert parse_mesh("1").size == 1          # "m" means "1xm"
    with pytest.raises(ValueError, match="bad mesh spec"):
        parse_mesh("2x")
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        parse_mesh("64x64")                   # more devices than exist


def test_trivial_placement_degenerates():
    p = Placement.single()
    assert p.is_trivial and p.size == 1 and p.describe() == "single"
    assert p.qualify("m7b") == "m7b"          # identity -> unchanged keys
    assert p.param_sharding("m7b", None, None) is None
    assert p.replicated_sharding() is None
    assert p.reshard_between_levels() is None
    import contextlib
    assert isinstance(p.mesh_context(), contextlib.nullcontext().__class__)


def test_placement_kinds_and_qualify():
    p = Placement.from_spec("1x1")
    p.auto_assign({"m68": 1.0, "m7b": 100.0}, "m7b")
    assert p.kind("m7b") == "tensor" and p.kind("m68") == "replicated"
    assert p.qualify("m7b") == "m7b@tensor:1x1"
    assert p.qualify("m68") == "m68@replicated:1x1"
    with pytest.raises(ValueError, match="unknown placement kind"):
        p.assign("m68", "diagonal")
    assert set(p.kinds.values()) <= set(KINDS)


def test_from_spec_passthrough():
    p = Placement.from_spec("1x1")
    assert Placement.from_spec(p) is p
    assert Placement.from_spec(p.mesh).describe() == "1x1"


def test_set_placement_after_placed_raises():
    pool = build_pool()
    pool.ensure_loaded("m68")
    with pytest.raises(RuntimeError, match="set_placement"):
        pool.set_placement(Placement.from_spec("1x1"))


# ---------------------------------------------------------------------------
# Exact memory accounting (the unload satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mesh", [None, "1x1"])
def test_load_unload_returns_usage_to_zero(mesh):
    """Repeated load/unload cycles must return per-device usage EXACTLY
    to zero: discharge reverses the precise charge taken at placement,
    never a recomputed (and clampable) estimate."""
    pool = build_pool(mesh, lazy=True)
    pl = pool.placement
    assert pl.total_usage() == 0
    for _ in range(3):
        pool.ensure_loaded("m68")
        pool.ensure_loaded("m7b")
        assert pl.total_usage() > 0
        assert all(v >= 0 for v in pl.usage.values())
        pool.unload("m68")
        pool.unload("m7b")
        assert pl.total_usage() == 0
        assert all(v == 0 for v in pl.usage.values())


def test_charge_matches_param_bytes_when_replicated():
    """On a 1x1 mesh every member is whole on the single device, so the
    placement's charge equals the analytic parameter byte count."""
    pool = build_pool("1x1", lazy=True)
    e = pool.ensure_loaded("m68")
    assert pool.placement.total_usage() == e.param_bytes()
    pool.unload("m68")
    assert pool.placement.total_usage() == 0


def test_recharge_is_idempotent():
    pool = build_pool("1x1", lazy=True)
    e = pool.ensure_loaded("m68")
    pool.placement.charge("m68", e.params, e.sharding)   # re-charge
    assert pool.placement.total_usage() == e.param_bytes()


# ---------------------------------------------------------------------------
# Placement-keyed scheduler T_i
# ---------------------------------------------------------------------------
def test_scheduler_t_i_is_placement_keyed():
    """The scheduler must read decode/verify EMAs under the placement-
    qualified key: the same model name on a different slice is a
    different cost."""
    placement = Placement.from_spec("1x1")
    placement.auto_assign({"m68": 1.0, "m7b": 100.0}, "m7b")
    prof = PerformanceProfiler()
    # evidence recorded the way the Executor records it on a placed pool
    prof.record("decode1", placement.qualify("m68"), 0.002)
    prof.record("decode1", placement.qualify("m7b"), 0.050)
    prof.record("verify", placement.qualify("m7b"), 0.055, block=5)
    sched = ModelChainScheduler(
        ["m68", "m7b"], "m7b", prof, SimilarityStore(),
        {"m68": 1.0, "m7b": 100.0}, qualify=placement.qualify)
    cost, _ = sched.predict_costs(("m68", "m7b"), 4)
    # the qualified EMAs (2 ms draft, 55 ms verify) were read, not the
    # cold defaults
    assert abs(cost - (4 * 0.002 + 0.055)) < 1e-6
    # an UNQUALIFIED scheduler over the same profiler sees no evidence
    cold = ModelChainScheduler(
        ["m68", "m7b"], "m7b", prof, SimilarityStore(),
        {"m68": 1.0, "m7b": 100.0})
    cold_cost, _ = cold.predict_costs(("m68", "m7b"), 4)
    assert cold_cost != cost


def test_router_profiler_keys_qualified_on_placed_pool():
    """Driving a real generate on a 1x1-placed pool records EMAs under
    the qualified keys (and NOT the bare model names)."""
    pool = build_pool("1x1")
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=("m68", "m7b"), fixed_window=3,
                    fused=False)
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                         (2, 5), 0, 61))
    r.generate(prompt, np.array([5, 4]), 6, request_id="q")
    models = {k[1] for k in r.profiler.emas}
    assert "m68@replicated:1x1" in models
    assert "m68" not in models


# ---------------------------------------------------------------------------
# Serving engine knob
# ---------------------------------------------------------------------------
def test_engine_mesh_knob_places_pool():
    from repro.serving import ServingEngine

    pool = build_pool()
    eng = ServingEngine(pool, "m7b", mesh="1x1")
    assert pool.placement.describe() == "1x1"
    assert pool.placement.kind("m7b") == "tensor"
    # a second engine over the SAME placed pool with the same spec is
    # fine (the example's A/B arms); a MISMATCHED placement is an error
    ServingEngine(pool, "m7b", mesh="1x1")
    with pytest.raises(ValueError):
        ServingEngine(pool, "m7b", mesh=Placement.single())
    del eng


# ---------------------------------------------------------------------------
# 1x1 anchor: full placement path, bit-identical output
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_mesh_1x1_paged_tree_session_bit_exact():
    """Paged session + tree chain on a 1x1-placed pool: admit, cycle,
    retire, readmit — committed streams bit-equal to the unmeshed pool
    (covers sharded prefill/insert/retire, paged rollback, and tree
    resolve on the placement path)."""
    outs = {}
    for mesh in (None, "1x1"):
        pool = build_pool(mesh)
        r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                        fixed_chain=("m68", "m7b"), fixed_tree="2x1x1",
                        fused=False, paged=True)
        prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                             (3, 7), 0, 61))
        plens = np.array([7, 5, 6])
        sess = r.start_session(2, 96, session_id="s")
        sess.admit(0, prompt[0, :plens[0]], 10)
        sess.admit(1, prompt[1, :plens[1]], 10)
        while sess.active.any():
            sess.run_cycle()
        a, b = sess.retire(0), sess.retire(1)
        sess.admit(0, prompt[2, :plens[2]], 10)
        while sess.active.any():
            sess.run_cycle()
        c = sess.retire(0)
        sess.close()
        outs[mesh] = (a, b, c)
    for x, y in zip(outs[None], outs["1x1"]):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# 8-virtual-device suite
# ---------------------------------------------------------------------------
@pytest.mark.mesh
@mesh8
def test_mesh_2x4_state_is_sharded():
    """ensure_loaded on a 2x4 pool actually commits NamedShardings: the
    tensor-parallel target's params land on the mesh, and the executor
    allocates session state under the placement."""
    from jax.sharding import NamedSharding

    pool = build_pool("2x4", lazy=True)   # lazy: unload can GC + discharge
    e = pool.ensure_loaded("m7b")
    assert e.placed and e.sharding is not None
    leaves = jax.tree.leaves(e.params)
    assert all(isinstance(x.sharding, NamedSharding) for x in leaves)
    specs = {tuple(x.sharding.spec) for x in leaves}
    assert any(any(ax is not None for ax in s) for s in specs), \
        "tensor placement produced only replicated leaves"
    assert pool.placement.total_usage() > 0
    pool.unload("m7b")
    assert pool.placement.total_usage() == 0


@pytest.mark.mesh
@mesh8
def test_mesh_2x4_session_lifecycle():
    """Sharded serving end to end on the 2x4 mesh: prefill/insert via a
    paged session, retire + readmit, paged rollback under speculation —
    greedy tokens equal the unmeshed pool's."""
    outs = {}
    for mesh in (None, "2x4"):
        pool = build_pool(mesh)
        r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                        fixed_chain=("m68", "m7b"), fixed_window=3,
                        fused=False, paged=True)
        prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                             (3, 7), 0, 61))
        plens = np.array([7, 5, 6])
        sess = r.start_session(2, 96, session_id="s8")
        sess.admit(0, prompt[0, :plens[0]], 8)
        sess.admit(1, prompt[1, :plens[1]], 8)
        while sess.active.any():
            sess.run_cycle()
        a, b = sess.retire(0), sess.retire(1)
        sess.admit(0, prompt[2, :plens[2]], 8)
        while sess.active.any():
            sess.run_cycle()
        c = sess.retire(0)
        sess.close()
        outs[mesh] = (a, b, c)
    for x, y in zip(outs[None], outs["2x4"]):
        np.testing.assert_array_equal(x, y)


@pytest.mark.mesh
@mesh8
def test_mesh_2x4_tree_resolve():
    """Token-tree speculation (draft_topk expansion, tree verify, tree
    resolve/rollback) on the 2x4 mesh matches the unmeshed stream."""
    outs = {}
    for mesh in (None, "2x4"):
        pool = build_pool(mesh)
        r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                        fixed_chain=("m68", "m7b"), fixed_tree="2x1x1",
                        fused=False)
        prompt = np.array(jax.random.randint(jax.random.PRNGKey(2),
                                             (2, 6), 0, 61))
        out = r.generate(prompt, np.array([6, 5]), 10, request_id="t8")
        outs[mesh] = out.generated
    for b in range(2):
        np.testing.assert_array_equal(outs[None][b], outs["2x4"][b])


@pytest.mark.mesh
@mesh8
def test_mesh_2x4_memory_accounting():
    """The load/unload-to-zero invariant on a REAL multi-device mesh,
    where tensor leaves charge shard-sized bytes to every device."""
    pool = build_pool("2x4", lazy=True)
    pl = pool.placement
    for _ in range(2):
        pool.ensure_loaded("m68")
        pool.ensure_loaded("m7b")
        devs = {d for d in pl.usage}
        assert len(devs) == 8            # charged across the whole mesh
        pool.unload("m68")
        pool.unload("m7b")
        assert pl.total_usage() == 0
        assert all(v == 0 for v in pl.usage.values())


# ---------------------------------------------------------------------------
# speclint conformance on placed pools (satellite: placement-aware tiers)
# ---------------------------------------------------------------------------
@pytest.mark.mesh
@pytest.mark.parametrize("mesh", ["1x1",
                                  pytest.param("2x4", marks=mesh8)])
def test_speclint_dynamic_tiers_green_on_mesh(mesh):
    """The jaxpr/HLO tiers must pass on PLACED pools: no unexplained
    collectives on the 1x1 mesh, collectives tolerated (expected) on the
    2x4 mesh, and the one-host-transfer-per-cycle runtime contract
    enforced on both."""
    from repro.analysis import harness, hlo_rules, jaxpr_rules

    cap = harness.capture_fused_linear(mesh=mesh)
    assert cap.placement is not None
    assert cap.placement.describe() == mesh
    findings = jaxpr_rules.run(cap) + hlo_rules.run(cap)
    assert not findings, [f.format() for f in findings]
