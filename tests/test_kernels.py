"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp oracles (interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# DTV kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,V,dtype", [
    (1, 100, jnp.float32), (5, 2048, jnp.float32), (8, 5000, jnp.bfloat16),
    (3, 2049, jnp.float32), (16, 300, jnp.bfloat16),
])
def test_dtv_matches_ref(B, V, dtype):
    ka, kb = jax.random.split(KEY)
    a = (jax.random.normal(ka, (B, V)) * 3).astype(dtype)
    b = (jax.random.normal(kb, (B, V)) * 3).astype(dtype)
    got = ops.dtv(a, b)
    want = ref.dtv_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    assert np.all(got >= -1e-6) and np.all(got <= 1 + 1e-6)


def test_softmax_stats_matches_ref():
    """The standalone stats kernel (dtv's first pass) against its oracle:
    padded tile boundaries and an uneven tail."""
    from repro.kernels import dtv as _dtv
    for R, V in [(_dtv.BLK_R, _dtv.BLK_V), (2 * _dtv.BLK_R, 2 * _dtv.BLK_V)]:
        x = (jax.random.normal(KEY, (R, V)) * 3).astype(jnp.float32)
        m, s = _dtv.softmax_stats(x)
        m_ref, s_ref = ref.softmax_stats_ref(x)
        np.testing.assert_allclose(m[:, 0], m_ref, rtol=1e-6)
        np.testing.assert_allclose(s[:, 0], s_ref, rtol=2e-5)


def test_dtv_identical_is_zero():
    a = jax.random.normal(KEY, (4, 1000))
    np.testing.assert_allclose(ops.dtv(a, a), 0.0, atol=1e-6)


def test_dtv_disjoint_is_one():
    a = jnp.full((2, 256), -100.0).at[:, 0].set(100.0)
    b = jnp.full((2, 256), -100.0).at[:, 1].set(100.0)
    np.testing.assert_allclose(ops.dtv(a, b), 1.0, atol=1e-5)


# ---------------------------------------------------------------------------
# Verify-stats kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,V,dtype", [
    (4, 511, jnp.float32), (8, 2048, jnp.float32), (12, 3000, jnp.bfloat16),
    (1, 130000, jnp.float32),
])
def test_verify_stats_matches_ref(R, V, dtype):
    kx, kc = jax.random.split(KEY)
    x = (jax.random.normal(kx, (R, V)) * 2).astype(dtype)
    cand = jax.random.randint(kc, (R,), 0, V)
    am, m, s, cl = ops.verify_row_stats(x, cand)
    am_r, m_r, s_r, cl_r = ref.verify_stats_ref(x, cand)
    np.testing.assert_array_equal(am, am_r)
    np.testing.assert_allclose(m, m_r, rtol=1e-6)
    np.testing.assert_allclose(s, s_r, rtol=2e-5)
    np.testing.assert_allclose(cl, cl_r, rtol=1e-6)


def test_greedy_accept_epilogue():
    x = jax.random.normal(KEY, (6, 777))
    cand = jnp.argmax(x, -1).astype(jnp.int32).at[3].add(1)  # row 3 mismatch
    am, m, s, cl = ops.verify_row_stats(x, cand)
    match, p = ops.greedy_accept_from_stats(cand, am, m, s, cl)
    want = np.ones(6, bool)
    want[3] = False
    np.testing.assert_array_equal(np.asarray(match), want)
    probs = jax.nn.softmax(x, -1)
    want_p = np.take_along_axis(np.asarray(probs),
                                np.asarray(cand)[:, None], 1)[:, 0]
    np.testing.assert_allclose(p, want_p, rtol=2e-5)


# ---------------------------------------------------------------------------
# Masked decode attention kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,Hkv,D,dtype", [
    (2, 128, 4, 2, 64, jnp.float32),
    (1, 700, 8, 8, 128, jnp.float32),      # unaligned S
    (3, 512, 25, 5, 64, jnp.bfloat16),     # hymba-style heads
    (2, 300, 48, 1, 128, jnp.float32),     # granite MQA
    (1, 1024, 32, 16, 168, jnp.bfloat16),  # gemma3 head_dim 168 (pad to 256)
])
def test_attention_matches_ref(B, S, H, Hkv, D, dtype):
    kq, kk, kv, km = jax.random.split(KEY, 4)
    q = jax.random.normal(kq, (B, H, D)).astype(dtype)
    k = jax.random.normal(kk, (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(kv, (B, S, Hkv, D)).astype(dtype)
    mask = jax.random.bernoulli(km, 0.7, (B, S))
    got = ops.masked_decode_attention(q, k, v, mask)
    want = ref.masked_decode_attention_ref(q, k, v, mask)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_attention_fully_masked_row_is_zero():
    q = jax.random.normal(KEY, (2, 4, 64))
    k = jax.random.normal(KEY, (2, 256, 2, 64))
    v = jax.random.normal(KEY, (2, 256, 2, 64))
    mask = jnp.zeros((2, 256), bool).at[1].set(True)
    out = ops.masked_decode_attention(q, k, v, mask)
    np.testing.assert_allclose(out[0], 0.0, atol=1e-6)
    assert float(jnp.max(jnp.abs(out[1]))) > 0


# ---------------------------------------------------------------------------
# Property-based sweeps (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 6), V=st.integers(2, 3000), seed=st.integers(0, 99))
def test_dtv_property(B, V, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (B, V)) * 4
    b = jax.random.normal(k2, (B, V)) * 4
    got = np.asarray(ops.dtv(a, b))
    want = np.asarray(ref.dtv_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
    # metric properties: symmetry + bounds
    got_sym = np.asarray(ops.dtv(b, a))
    np.testing.assert_allclose(got, got_sym, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(1, 600), Hkv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 5]), seed=st.integers(0, 99))
def test_attention_property(S, Hkv, g, seed):
    kk = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(kk, 4)
    B, D = 2, 64
    H = Hkv * g
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, Hkv, D))
    v = jax.random.normal(k3, (B, S, Hkv, D))
    mask = jax.random.bernoulli(k4, 0.5, (B, S))
    got = np.asarray(ops.masked_decode_attention(q, k, v, mask))
    want = np.asarray(ref.masked_decode_attention_ref(q, k, v, mask))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
