"""Tree-speculation kernels vs their pure-jnp oracles (interpret mode on
CPU).  Kept hypothesis-free so the suite runs everywhere — unlike
tests/test_kernels.py, which importorskips hypothesis at module level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Tree-block attention kernel (per-query ancestor mask rows)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,S,H,Hkv,D,dtype", [
    (2, 6, 128, 4, 2, 64, jnp.float32),
    (1, 10, 700, 8, 8, 128, jnp.float32),   # unaligned S
    (3, 4, 300, 48, 1, 128, jnp.float32),   # MQA
    (2, 7, 512, 8, 4, 80, jnp.bfloat16),    # head_dim pad to 128
])
def test_tree_attention_matches_ref(B, T, S, H, Hkv, D, dtype):
    kq, kk, kv, km = jax.random.split(KEY, 4)
    q = jax.random.normal(kq, (B, T, H, D)).astype(dtype)
    k = jax.random.normal(kk, (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(kv, (B, S, Hkv, D)).astype(dtype)
    mask = jax.random.bernoulli(km, 0.6, (B, T, S))
    got = ops.masked_tree_attention(q, k, v, mask)
    want = ref.masked_tree_attention_ref(q, k, v, mask)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_tree_attention_t1_equals_decode_kernel():
    """The single-token decode kernel is the T=1 special case."""
    kq, kk, kv, km = jax.random.split(KEY, 4)
    B, S, H, Hkv, D = 2, 256, 4, 2, 64
    q = jax.random.normal(kq, (B, 1, H, D))
    k = jax.random.normal(kk, (B, S, Hkv, D))
    v = jax.random.normal(kv, (B, S, Hkv, D))
    mask = jax.random.bernoulli(km, 0.7, (B, 1, S))
    tree = ops.masked_tree_attention(q, k, v, mask)[:, 0]
    dec = ops.masked_decode_attention(q[:, 0], k, v, mask[:, 0])
    np.testing.assert_allclose(np.asarray(tree), np.asarray(dec),
                               rtol=2e-5, atol=2e-5)


def test_tree_attention_fully_masked_row_is_zero():
    q = jax.random.normal(KEY, (1, 3, 4, 64))
    k = jax.random.normal(KEY, (1, 256, 2, 64))
    v = jax.random.normal(KEY, (1, 256, 2, 64))
    mask = jnp.zeros((1, 3, 256), bool).at[:, 1].set(True)
    out = ops.masked_tree_attention(q, k, v, mask)
    np.testing.assert_allclose(out[0, 0], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[0, 2], 0.0, atol=1e-6)
    assert float(jnp.max(jnp.abs(out[0, 1]))) > 0


# ---------------------------------------------------------------------------
# Draft top-k kernel (greedy tree expansion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,V,k", [
    (4, 61, 1), (8, 512, 2), (5, 2048, 3), (3, 2100, 4), (1, 300, 2),
])
def test_draft_topk_matches_ref(R, V, k):
    x = jax.random.normal(KEY, (R, V)) * 2
    gv, gi = ops.draft_topk(x, k)
    wv, wi = ref.topk_ref(x, k)
    np.testing.assert_allclose(gv, wv, rtol=1e-6)
    np.testing.assert_array_equal(gi, wi)


def test_draft_topk_tie_breaking_matches_argmax():
    """Duplicated maxima resolve to the FIRST index — the k=1 column must
    equal jnp.argmax bit-for-bit (linear greedy drafting parity)."""
    x = np.zeros((3, 400), np.float32)
    x[0, [7, 300]] = 5.0          # duplicate max
    x[1, [2, 3]] = 1.5            # duplicates inside one tile
    x[2, :] = -1.0                # all-equal row
    xj = jnp.asarray(x)
    _, gi = ops.draft_topk(xj, 2)
    np.testing.assert_array_equal(
        np.asarray(gi)[:, 0], np.asarray(jnp.argmax(xj, -1)))
    wv, wi = ref.topk_ref(xj, 2)
    np.testing.assert_array_equal(gi, wi)
