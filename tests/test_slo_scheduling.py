"""Serving-conformance suite for SLO-aware goodput scheduling.

Pins, with deterministic synthetic load where possible:
  * LoadSignal pressure math and the goodput objective (score_choice);
  * the degenerate no-SLO path — bit-identical choices AND tables vs the
    latency-only scheduler (today's behaviour must survive the refactor);
  * shrink-under-pressure / deepen-when-idle window dynamics;
  * Eq. 7 memo invalidation across a load-signal step change (a stale
    memo would keep serving deep speculation into a saturated engine);
  * the slot-TPOT infeasibility penalty;
  * ServingMetrics against a straight-numpy oracle (incl. NaN guards);
  * engine-level EDF admission and the TTFT shed policy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LoadSignal, ModelChainScheduler, ModelPool,
                        PerformanceProfiler, SimilarityStore)
from repro.data import (CorpusConfig, Request, SyntheticCorpus,
                        make_bursty_workload)
from repro.models import ModelConfig
from repro.models.model import LanguageModel
from repro.serving import ServingEngine


def _mk(slo_aware=True, **kw):
    """Two-model pool with pinned EMAs: d=1ms draft, t=100ms target,
    sim 0.95 — deep speculation clearly optimal when idle."""
    prof = PerformanceProfiler()
    prof.record("decode1", "d", 0.001)
    prof.record("decode1", "t", 0.1)
    store = SimilarityStore()
    store.update("d", "t", 0.05)
    kw.setdefault("windows", (1, 2, 4, 8))
    kw.setdefault("switch_penalty_steps", 1e9)
    return ModelChainScheduler(["d", "t"], "t", prof, store,
                               {"d": 1, "t": 100}, slo_aware=slo_aware,
                               **kw)


def _pressure(p, slots=8):
    """LoadSignal with the given pressure (full occupancy, queue scaled)."""
    return LoadSignal(queue_depth=int(round(p * slots)), occupancy=1.0,
                      cycle_ema_s=0.01, num_slots=slots)


# ---------------------------------------------------------------------------
# load-signal math
# ---------------------------------------------------------------------------
def test_load_signal_pressure_pinned():
    # empty queue -> zero pressure regardless of occupancy: a
    # full-but-keeping-up engine must still speculate deep
    assert LoadSignal(0, 1.0, 0.5, 4).pressure == 0.0
    # saturated: queue >= slots, all busy
    assert LoadSignal(4, 1.0, 0.5, 4).pressure == 1.0
    assert LoadSignal(8, 1.0, 0.5, 4).pressure == 1.0      # queue clipped
    assert LoadSignal(4, 1.5, 0.5, 4).pressure == 1.0      # occ clipped
    # partial: (2/4) * 0.5
    assert LoadSignal(2, 0.5, 0.5, 4).pressure == pytest.approx(0.25)
    assert LoadSignal(2, 0.0, 0.5, 4).pressure == 0.0
    assert LoadSignal(2, 0.5, 0.5, 0).pressure == 0.0      # no slots


def test_score_choice_math_pinned():
    sched = _mk()   # load_beta=8, slo_miss_penalty=4 defaults
    sched.set_load(LoadSignal(4, 0.5, 0.01, 8))  # pressure 0.25
    assert sched.score_choice(0.02, 0.1) == pytest.approx(
        0.02 + 0.25 * 8.0 * 0.1)
    # slot TPOT SLO: infeasible option pays the soft penalty...
    sched.set_slot_slo("s", tpot_slo_s=0.01)
    assert sched.score_choice(0.02, 0.1, slot="s") == pytest.approx(
        0.02 + 0.25 * 8.0 * 0.1 + 4.0 * (0.02 - 0.01))
    # ...a feasible one doesn't
    assert sched.score_choice(0.005, 0.1, slot="s") == pytest.approx(
        0.005 + 0.25 * 8.0 * 0.1)
    # without load the objective IS t_eff, even with slo_aware on
    sched.set_load(None)
    assert sched.score_choice(0.02, 0.1, slot="s") == 0.02


# ---------------------------------------------------------------------------
# degenerate no-SLO path: bit-identical to the latency-only scheduler
# ---------------------------------------------------------------------------
def test_no_slo_path_is_bit_identical():
    base = _mk(slo_aware=False)
    want = base.get_optimal_chain()
    assert want.score == want.predicted_t_eff   # objective == T_eff

    # slo_aware off + load set: still latency-only
    a = _mk(slo_aware=False)
    a.set_load(_pressure(1.0))
    got_a = a.get_optimal_chain()
    # slo_aware on but NO load signal (bare scheduler user): latency-only
    b = _mk(slo_aware=True)
    got_b = b.get_optimal_chain()
    for got in (got_a, got_b):
        assert got.chain == want.chain and got.window == want.window
        assert got.predicted_t_eff == want.predicted_t_eff
        assert got.score == want.score
        assert got.table == want.table          # every candidate identical
    # and the memo snapshot carries no load/SLO keys -> identical reuse
    assert not any(k[0] in ("load", "slo") for k in a._inputs_snapshot())
    assert not any(k[0] in ("load", "slo") for k in b._inputs_snapshot())


def test_idle_goodput_path_matches_latency_only():
    """pressure == 0 (active goodput objective, nothing queued): the
    score reduces to exactly T_eff — idle engines speculate as deep as
    today."""
    base = _mk(slo_aware=False)
    want = base.get_optimal_chain()
    sched = _mk(slo_aware=True)
    sched.set_load(_pressure(0.0))
    got = sched.get_optimal_chain()
    assert (got.chain, got.window) == (want.chain, want.window)
    assert got.table == pytest.approx(want.table)
    assert got.window == 8                       # deep when idle


# ---------------------------------------------------------------------------
# shrink under pressure / deepen when idle
# ---------------------------------------------------------------------------
def test_window_shrinks_under_pressure_to_target_only():
    sched = _mk()
    chosen = []
    for p in (0.0, 0.125, 0.25, 1.0):
        sched.set_load(_pressure(p))
        c = sched.get_optimal_chain()
        cost, _ = sched.predict_costs(c.chain, c.window, tree=c.tree)
        chosen.append((p, c, cost))
    # endpoints pinned: idle -> deep W=8 chain; saturated -> target-only
    assert chosen[0][1].chain == ("d", "t") and chosen[0][1].window == 8
    assert chosen[-1][1].chain == ("t",)
    # speculation depth (and thus cycle wall) shrinks monotonically
    windows = [c.window if len(c.chain) > 1 else 0 for _, c, _ in chosen]
    assert windows == sorted(windows, reverse=True)
    costs = [cost for _, _, cost in chosen]
    assert costs == sorted(costs, reverse=True)
    # intermediate pressure keeps SOME speculation (not a cliff)
    assert len(chosen[1][1].chain) > 1


def test_deepen_when_pressure_recedes():
    sched = _mk()
    sched.set_load(_pressure(1.0))
    assert sched.get_optimal_chain().chain == ("t",)
    sched.set_load(_pressure(0.0))
    c = sched.get_optimal_chain()
    assert c.chain == ("d", "t") and c.window == 8


def test_tpot_penalty_keeps_speculation_for_tight_slots():
    """At saturation the pressure term alone prefers target-only — but a
    slot whose TPOT SLO the target-only T_eff (0.1 s/token) would blow
    keeps a shallow speculative chain instead (0.057 s/token feasible
    region), while a no-SLO slot in the same sweep drops to target-only."""
    sched = _mk()
    sched.set_load(_pressure(1.0))
    assert sched.get_optimal_chain(slot="free").chain == ("t",)
    sched.set_slot_slo("tight", tpot_slo_s=0.04)
    c = sched.get_optimal_chain(slot="tight")
    assert c.chain == ("d", "t")
    assert c.predicted_t_eff < 0.1               # faster than target-only


# ---------------------------------------------------------------------------
# memo invalidation across load / SLO step changes (regression)
# ---------------------------------------------------------------------------
def test_memo_invalidated_on_load_step_change():
    sched = _mk()
    sched.set_load(_pressure(0.0))
    c1 = sched.get_optimal_chain()
    assert sched.eval_count == 1
    assert sched.get_optimal_chain() is c1 and sched.reuse_count == 1
    # an equal-valued fresh LoadSignal is NOT drift
    sched.set_load(_pressure(0.0))
    assert sched.get_optimal_chain() is c1 and sched.reuse_count == 2
    # a load step change MUST invalidate the memo — a stale argmin would
    # keep running deep speculation into a saturated engine
    sched.set_load(_pressure(1.0))
    c2 = sched.get_optimal_chain()
    assert sched.eval_count == 2 and c2.chain == ("t",)
    # ...and stepping back down re-deepens
    sched.set_load(_pressure(0.0))
    c3 = sched.get_optimal_chain()
    assert sched.eval_count == 3
    assert c3.chain == ("d", "t") and c3.window == 8
    # clearing the load changes the snapshot key set: latency-only again
    sched.set_load(None)
    c4 = sched.get_optimal_chain()
    assert sched.eval_count == 4 and c4.score == c4.predicted_t_eff


def test_slot_memo_invalidated_on_load_and_slo_change():
    sched = _mk()
    sched.set_load(_pressure(0.0))
    c1 = sched.get_optimal_chain(slot="s0")
    assert sched.eval_count == 1
    assert sched.get_optimal_chain(slot="s0") is c1
    sched.set_load(_pressure(1.0))
    c2 = sched.get_optimal_chain(slot="s0")
    assert sched.eval_count == 2 and c2.chain == ("t",)
    # attaching a TPOT SLO to the slot is also snapshot drift
    sched.set_slot_slo("s0", tpot_slo_s=0.04)
    c3 = sched.get_optimal_chain(slot="s0")
    assert sched.eval_count == 3 and c3.chain == ("d", "t")
    # release clears the slot's SLO alongside its memo
    sched.release_slot("s0")
    assert "s0" not in sched._slot_slo and "s0" not in sched._slot_choice


# ---------------------------------------------------------------------------
# ServingMetrics vs numpy oracle (engine-level tests below need the pool)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def pool():
    p = ModelPool()
    for (n, L, d, s) in [("s", 2, 32, 1), ("t", 3, 48, 2)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=64, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


def _oracle_slo_met(r):
    """Independent re-derivation of Request.slo_met from raw fields."""
    if r.shed or r.finish_s < 0:
        return False
    if r.ttft_slo_s is not None \
            and (r.first_token_s - r.arrival_s) > r.ttft_slo_s:
        return False
    if r.tpot_slo_s is not None and r.generated > 1 \
            and (r.finish_s - r.first_token_s) / (r.generated - 1) \
            > r.tpot_slo_s:
        return False
    return True


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_metrics_match_numpy_oracle(pool, seed):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(60):
        arr = float(rng.uniform(0, 10))
        start = arr + float(rng.uniform(0, 0.5))
        first = start + float(rng.uniform(0, 0.5))
        r = Request(f"r{i}", arr, np.array([1, 2]), 32, "synthetic",
                    ttft_slo_s=(float(rng.uniform(0.1, 1.5))
                                if rng.random() < 0.7 else None),
                    tpot_slo_s=(float(rng.uniform(0.01, 0.3))
                                if rng.random() < 0.7 else None),
                    start_s=start, first_token_s=first,
                    finish_s=first + float(rng.uniform(0, 3)),
                    generated=int(rng.integers(1, 30)))
        u = rng.random()
        if u < 0.12:          # shed: never served at all
            r.shed = True
            r.start_s = r.first_token_s = r.finish_s = -1.0
            r.generated = 0
        elif u < 0.18:        # admitted but never finished
            r.finish_s = -1.0
        reqs.append(r)
    acc = [float(x) for x in rng.uniform(1, 4, size=9)]
    eng = ServingEngine(pool, "t", slo_latency_s=3.0)
    m = eng._metrics(reqs, acc)

    done = [r for r in reqs if r.finish_s >= 0]
    ttfts = np.array([r.first_token_s - r.arrival_s for r in done])
    lats = np.array([r.finish_s - r.arrival_s for r in done])
    tpots = np.array([(r.finish_s - r.first_token_s) / (r.generated - 1)
                      for r in done if r.generated > 1])
    queues = np.array([r.start_s - r.arrival_s for r in done])
    makespan = (max(r.finish_s for r in done)
                - min(r.arrival_s for r in done))
    assert m.num_requests == len(done)
    assert m.makespan_s == pytest.approx(makespan)
    assert m.avg_ttft_s == pytest.approx(ttfts.mean())
    assert m.p95_ttft_s == pytest.approx(np.percentile(ttfts, 95))
    assert m.avg_latency_s == pytest.approx(lats.mean())
    assert m.p95_latency_s == pytest.approx(np.percentile(lats, 95))
    assert m.avg_tpot_s == pytest.approx(tpots.mean())
    assert m.avg_queue_s == pytest.approx(queues.mean())
    assert m.slo_attainment == pytest.approx(np.mean(lats <= 3.0))
    assert m.total_tokens == sum(r.generated for r in done)
    assert m.goodput_tps == pytest.approx(m.total_tokens / makespan)
    assert m.request_throughput_rps == pytest.approx(len(done) / makespan)
    assert m.avg_acceptance_len == pytest.approx(np.mean(acc))
    met = np.array([_oracle_slo_met(r) for r in reqs])
    assert m.request_slo_attainment == pytest.approx(met.mean())
    assert m.slo_goodput_rps == pytest.approx(
        sum(_oracle_slo_met(r) for r in done) / makespan)
    assert m.num_shed == sum(r.shed for r in reqs)


def test_metrics_all_shed_population(pool):
    """Everything shed: done-set empty, attainment 0 (not NaN — the
    offered population is non-empty), rates NaN-guarded."""
    rs = [Request(f"r{i}", 0.0, np.array([1]), 4, "s",
                  ttft_slo_s=0.1, shed=True) for i in range(3)]
    m = ServingEngine(pool, "t")._metrics(rs, [])
    assert m.num_shed == 3 and m.num_requests == 0
    assert m.request_slo_attainment == 0.0
    assert np.isnan(m.goodput_tps) and np.isnan(m.slo_goodput_rps)


# ---------------------------------------------------------------------------
# engine-level EDF admission + shed policy
# ---------------------------------------------------------------------------
def _req(rid, seed, arrival, lp, budget, ttft=None, tpot=None):
    rng = np.random.default_rng(seed)
    return Request(rid, arrival,
                   rng.integers(1, 64, size=lp).astype(np.int64),
                   budget, "synthetic", ttft_slo_s=ttft, tpot_slo_s=tpot)


def test_edf_admission_order(pool):
    """Three simultaneous arrivals on ONE slot: service order must follow
    TTFT deadlines, not submission order."""
    reqs = [_req("r0", 0, 0.0, 6, 4, ttft=100.0),
            _req("r1", 1, 0.0, 6, 4, ttft=5.0),
            _req("r2", 2, 0.0, 6, 4, ttft=50.0)]
    eng = ServingEngine(pool, "t", batch_size=1,
                        router_kwargs=dict(adaptive=False,
                                           fixed_chain=("t",),
                                           fixed_window=1))
    eng.run(list(reqs))
    start = {r.request_id: r.start_s for r in reqs}
    assert start["r1"] < start["r2"] < start["r0"]
    for r in reqs:
        assert r.finish_s >= 0 and r.output_tokens is not None


def test_shed_policy_drops_unmeetable(pool):
    """One busy slot; a queued request whose TTFT deadline passes while
    it waits is dropped (never admitted), and counts as an SLO miss."""
    r0 = _req("r0", 0, 0.0, 6, 6)                       # no SLO
    r1 = _req("r1", 1, 0.001, 6, 4, ttft=0.004)         # doomed: ~4 ms
    eng = ServingEngine(pool, "t", batch_size=1, shed_policy="ttft",
                        router_kwargs=dict(adaptive=False,
                                           fixed_chain=("t",),
                                           fixed_window=1))
    m = eng.run([r0, r1])
    assert r1.shed and r1.finish_s < 0 and r1.output_tokens is None
    assert not r0.shed and r0.finish_s > 0
    assert m.num_shed == 1
    assert m.request_slo_attainment == pytest.approx(0.5)


def test_shed_policy_validated(pool):
    with pytest.raises(ValueError, match="shed_policy"):
        ServingEngine(pool, "t", shed_policy="bogus")


def test_slo_serving_integration(pool):
    """Bursty SLO workload end-to-end with the goodput objective on:
    engine-level TPOT default fills unset axes, the load signal is
    cleared after the run, and every request still completes."""
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=64))
    reqs = make_bursty_workload(corpus, "gsm8k", rate_on_rps=6.0,
                                duration_s=2.0, mean_on_s=0.5,
                                mean_off_s=0.5, seed=4, scale=0.08,
                                max_prompt=12, max_out=6, ttft_slo=30.0)
    assert len(reqs) >= 2
    eng = ServingEngine(pool, "t", batch_size=2, slo_aware=True,
                        tpot_slo_s=5.0,
                        router_kwargs=dict(adaptive=True))
    m = eng.run(reqs)
    sched = eng._router.scheduler
    assert sched.slo_aware and sched._load is None   # scoped to the run
    assert m.num_requests == len(reqs) and m.num_shed == 0
    assert 0.0 <= m.request_slo_attainment <= 1.0
    assert np.isfinite(m.slo_goodput_rps)
    for r in reqs:
        assert r.ttft_slo_s == 30.0 and r.tpot_slo_s == 5.0
        assert r.output_tokens is not None
        assert r.generated == len(r.output_tokens)
