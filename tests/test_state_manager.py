"""State management invariants (paper §4.4): logical rollback (Eq. 8),
pointer-rewind physical reclaim (Eq. 9 TPU analogue), defragmentation,
and equivalence of rollback vs from-scratch recompute."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import ModelConfig
from repro.models import kv_cache as kvc
from repro.models.model import LanguageModel


def tiny_cfg(**kw):
    d = dict(name="t", arch_type="dense", num_layers=2, d_model=32,
             num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=41,
             dtype=jnp.float32)
    d.update(kw)
    return ModelConfig(**d)


def test_append_rollback_lengths():
    st_ = kvc.make_state(2, 32, {})
    toks = jnp.arange(10).reshape(2, 5).astype(jnp.int32)
    st_, q_pos, slot = kvc.append_tokens(st_, toks)
    assert int(slot) == 0 and int(st_.write_ptr) == 5
    np.testing.assert_array_equal(st_.length, [5, 5])
    st_ = kvc.rollback(st_, jnp.array([2, 0]))
    np.testing.assert_array_equal(st_.length, [3, 5])
    # ptr only reclaims the COMMON suffix (row 1 still valid to slot 4)
    assert int(st_.write_ptr) == 5
    st_ = kvc.rollback(st_, jnp.array([0, 2]))
    assert int(st_.write_ptr) == 3


def test_mask_decouples_validity_from_storage():
    """Paper Fig. 3: invalid entries physically present but ignored."""
    st_ = kvc.make_state(1, 16, {})
    st_, _, _ = kvc.append_tokens(st_, jnp.array([[7, 8, 9]], jnp.int32))
    st_ = kvc.logical_rollback(st_, jnp.array([2]))
    # data still physically there
    np.testing.assert_array_equal(st_.token_buf[0, :3], [7, 8, 9])
    np.testing.assert_array_equal(st_.mask[0, :3], [True, False, False])


def test_valid_mask_partial_append():
    st_ = kvc.make_state(2, 16, {})
    valid = jnp.array([[True, True], [True, False]])
    st_, q_pos, _ = kvc.append_tokens(
        st_, jnp.array([[1, 2], [3, 4]], jnp.int32), valid)
    np.testing.assert_array_equal(st_.length, [2, 1])
    assert int(q_pos[1, 1]) >= 2 ** 29   # invalid -> far-future position


def test_defragment_compacts_holes():
    st_ = kvc.make_state(2, 32, {})
    st_, _, _ = kvc.append_tokens(
        st_, jnp.arange(1, 13).reshape(2, 6).astype(jnp.int32))
    st_ = kvc.logical_rollback(st_, jnp.array([3, 1]))
    st_, _, _ = kvc.append_tokens(
        st_, jnp.array([[91, 92], [93, 94]], jnp.int32))
    frag_before = float(kvc.fragmentation(st_))
    d = kvc.defragment(st_)
    # only raggedness-induced holes remain (rows have different lengths and
    # share one physical pointer); true fragmentation is gone
    lens = np.asarray(d.length)
    residual = float(np.mean(lens.max() - lens) / lens.max())
    assert float(kvc.fragmentation(d)) <= residual + 1e-6
    assert float(kvc.fragmentation(d)) < frag_before
    assert int(d.write_ptr) == int(lens.max())
    # logical stream preserved
    for b, want in enumerate([[1, 2, 3, 91, 92], [7, 8, 9, 10, 11, 93, 94]]):
        order = np.argsort(np.where(d.mask[b], d.pos_buf[b], 1 << 30))
        got = np.asarray(d.token_buf[b])[order][:int(d.length[b])]
        np.testing.assert_array_equal(got, want)


def test_rollback_equals_recompute():
    """Decode 4 tokens, roll back 2, decode 2 more == decode the final
    sequence from scratch (state consistency, greedy logits equality)."""
    cfg = tiny_cfg()
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    B = 2
    base = jnp.array([[5, 6, 7], [8, 9, 10]], jnp.int32)
    extra = jnp.array([[11, 12, 13, 14], [15, 16, 17, 18]], jnp.int32)

    st1, _ = lm.make_state(B, 32)
    _, st1 = lm.prefill(params, st1, base)
    _, st1 = lm.decode(params, st1, extra)
    st1 = lm.rollback(st1, jnp.array([2, 2]))
    lg1, st1 = lm.decode(params, st1, jnp.array([[21, 22], [23, 24]],
                                                jnp.int32))

    st2, _ = lm.make_state(B, 32)
    _, st2 = lm.prefill(params, st2, base)
    _, st2 = lm.decode(params, st2, extra[:, :2])
    lg2, st2 = lm.decode(params, st2, jnp.array([[21, 22], [23, 24]],
                                                jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch_kw", [
    dict(arch_type="ssm", num_kv_heads=4, d_ff=0,
         ssm=__import__("repro.models.config", fromlist=["SSMConfig"]
                        ).SSMConfig(slstm_every=2)),
    dict(arch_type="hybrid", sliding_window=8,
         ssm=__import__("repro.models.config", fromlist=["SSMConfig"]
                        ).SSMConfig(state_size=4, expand=2)),
])
def test_ssm_rollback_equals_recompute(arch_kw):
    """DESIGN §5: snapshot-ring rollback for recurrent state."""
    cfg = tiny_cfg(**arch_kw)
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.PRNGKey(1))
    B = 2
    base = jnp.array([[5, 6, 7], [8, 9, 10]], jnp.int32)
    extra = jnp.array([[11, 12, 13, 14], [15, 16, 17, 18]], jnp.int32)
    nxt = jnp.array([[21, 22], [23, 24]], jnp.int32)

    st1, _ = lm.make_state(B, 32, with_snaps=True)
    _, st1 = lm.prefill(params, st1, base)
    _, st1 = lm.decode(params, st1, extra)
    st1 = lm.rollback(st1, jnp.array([2, 2]))
    lg1, _ = lm.decode(params, st1, nxt)

    st2, _ = lm.make_state(B, 32, with_snaps=True)
    _, st2 = lm.prefill(params, st2, base)
    _, st2 = lm.decode(params, st2, extra[:, :2])
    lg2, _ = lm.decode(params, st2, nxt)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-4, atol=1e-4)


def test_ssm_rollback_per_row_divergent():
    from repro.models.config import SSMConfig
    cfg = tiny_cfg(arch_type="ssm", num_kv_heads=4, d_ff=0,
                   ssm=SSMConfig(slstm_every=2))
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.PRNGKey(2))
    B = 2
    base = jnp.array([[5, 6], [8, 9]], jnp.int32)
    extra = jnp.array([[11, 12, 13], [15, 16, 17]], jnp.int32)
    nxt = jnp.array([[21], [23]], jnp.int32)

    st1, _ = lm.make_state(B, 32, with_snaps=True)
    _, st1 = lm.prefill(params, st1, base)
    _, st1 = lm.decode(params, st1, extra)
    st1 = lm.rollback(st1, jnp.array([1, 3]))     # divergent rollback
    lg1, _ = lm.decode(params, st1, nxt)

    # row 0 reference: kept 2 of the extras
    st2, _ = lm.make_state(B, 32, with_snaps=True)
    _, st2 = lm.prefill(params, st2, base)
    _, st2 = lm.decode(params, st2, extra[:, :2])
    lg2, _ = lm.decode(params, st2, nxt)
    np.testing.assert_allclose(np.asarray(lg1[0]), np.asarray(lg2[0]),
                               rtol=1e-4, atol=1e-4)
    # row 1 reference: kept none
    st3, _ = lm.make_state(B, 32, with_snaps=True)
    _, st3 = lm.prefill(params, st3, base)
    lg3, _ = lm.decode(params, st3, nxt)
    np.testing.assert_allclose(np.asarray(lg1[1]), np.asarray(lg3[1]),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(
    st.tuples(st.integers(1, 4),            # append length
              st.integers(0, 3), st.integers(0, 3)),  # rollbacks per row
    min_size=1, max_size=6))
def test_state_property_stream_consistency(ops):
    """Property: after any append/rollback interleaving, the logical stream
    equals the reference stream maintained in plain Python."""
    st_ = kvc.make_state(2, 128, {})
    ref = [[], []]
    tok = 1
    for (n, r0, r1) in ops:
        toks = np.arange(tok, tok + 2 * n).reshape(2, n).astype(np.int32)
        tok += 2 * n
        st_, _, _ = kvc.append_tokens(st_, jnp.asarray(toks))
        for b in range(2):
            ref[b].extend(toks[b].tolist())
        r = [min(r0, len(ref[0])), min(r1, len(ref[1]))]
        st_ = kvc.rollback(st_, jnp.asarray(r))
        for b in range(2):
            if r[b]:
                del ref[b][-r[b]:]
    for b in range(2):
        order = np.argsort(np.where(st_.mask[b], st_.pos_buf[b], 1 << 30))
        got = np.asarray(st_.token_buf[b])[order][:int(st_.length[b])]
        np.testing.assert_array_equal(got, ref[b])
