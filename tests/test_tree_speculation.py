"""Tree-structured multi-level speculation (SpecInfer-style token trees).

Key guarantees under test:
  - branching-factor-1 tree decode is BIT-IDENTICAL to the existing linear
    greedy path (the linear window is the degenerate tree);
  - multi-branch trees still commit exactly the target-only greedy stream
    (pruning/branching change *when* tokens arrive, never *which*), and
    accept at least as many tokens per cycle as the equal-depth linear
    draft on the same seed (the tree contains the linear top-1 path);
  - per-level pruning (3-model chains) preserves bit-equality;
  - tree state resolution (commit winning path, mask dead branches) keeps
    every model's cache consistent with the committed stream.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool, TokenTree
from repro.core import verification as ver
from repro.models import ModelConfig
from repro.models import kv_cache as kvc
from repro.models.model import LanguageModel

pytestmark = pytest.mark.slow   # full tree-cycle sweep, ~2 min on CPU


@pytest.fixture(scope="module")
def pool():
    # same tiny configs as tests/test_equivalence.py
    p = ModelPool()
    for (n, L, d, s) in [("m68", 2, 32, 1), ("m1b", 3, 48, 2),
                         ("m7b", 4, 64, 3)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=61, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


@pytest.fixture(scope="module")
def reference(pool):
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                         (3, 7), 0, 61))
    plens = np.array([7, 5, 6])
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=("m7b",), fixed_window=1)
    ref = r.generate(prompt, plens, 14, request_id="ref")
    return prompt, plens, ref


# ---------------------------------------------------------------------------
# TokenTree structure
# ---------------------------------------------------------------------------
def test_token_tree_structure():
    t = TokenTree((2, 2, 1))
    assert t.num_nodes == 10 and t.depth_levels == 3
    assert t.level_sizes == (2, 4, 4)
    np.testing.assert_array_equal(
        t.parent, [-1, -1, 0, 0, 1, 1, 2, 3, 4, 5])
    # every path walks parent links root -> leaf
    for row in t.paths:
        for d in range(1, len(row)):
            assert t.parent[row[d]] == row[d - 1]
    # ancestor mask: self + transitive parents, nothing else
    assert t.attend[7, 0] and t.attend[7, 3] and t.attend[7, 7]
    assert not t.attend[7, 1] and not t.attend[7, 2] and not t.attend[2, 3]
    # linear degenerate case
    lin = TokenTree.linear(4)
    assert lin.is_linear and lin.num_nodes == 4
    np.testing.assert_array_equal(lin.paths, [[0, 1, 2, 3]])
    assert TokenTree.parse("2x2x1") == t and str(t) == "2x2x1"


def test_verify_tree_branch1_matches_linear_rule():
    """The tree greedy rule on a branching-1 tree IS verify_greedy."""
    rng = np.random.default_rng(0)
    B, W, V = 3, 4, 17
    tree = TokenTree.linear(W)
    cand = jnp.asarray(rng.integers(0, V, (B, W)), jnp.int32)
    logits = jnp.asarray(rng.standard_normal((B, W + 1, V)), jnp.float32)
    lin = ver.verify_greedy(cand, logits)
    tr = ver.verify_tree(tree, cand, logits, jnp.ones((B, W), bool))
    np.testing.assert_array_equal(lin.num_accepted, tr.num_accepted)
    np.testing.assert_array_equal(lin.next_token, tr.next_token)
    np.testing.assert_allclose(lin.next_probs, tr.next_probs, rtol=1e-6)


def test_verify_tree_picks_deepest_surviving_path():
    tree = TokenTree((2, 1))          # nodes: roots 0,1; children 2,3
    V = 5
    lg = np.full((1, tree.num_nodes + 1, V), -5.0, np.float32)
    lg[0, 0, 2] = 5.0                 # t_last argmax: token 2
    lg[0, 2, 4] = 5.0                 # after node 1: argmax token 4
    lg[0, 4, 3] = 5.0                 # after node 3: bonus argmax 3
    cand = jnp.asarray([[9, 2, 7, 4]], jnp.int32)   # node1=2 ✓, node3=4 ✓
    res = ver.verify_tree(tree, cand, jnp.asarray(lg),
                          jnp.ones((1, tree.num_nodes), bool))
    assert int(res.num_accepted[0]) == 2
    np.testing.assert_array_equal(res.path_nodes[0], [1, 3])
    assert int(res.next_token[0]) == 3
    # pruning node 1 kills the whole surviving path
    nv = jnp.asarray([[True, False, True, True]])
    res2 = ver.verify_tree(tree, cand, jnp.asarray(lg), nv)
    assert int(res2.num_accepted[0]) == 0
    assert int(res2.next_token[0]) == 2   # correction = t_last argmax


def test_resolve_tree_masks_dead_branches():
    st = kvc.make_state(2, 16, {})
    toks = jnp.arange(6, dtype=jnp.int32).reshape(2, 3)
    st, _, _ = kvc.append_tokens(st, toks)                 # 3 committed
    tree_toks = jnp.ones((2, 4), jnp.int32)
    sd = jnp.array([0, 0, 1, 1], jnp.int32)                # (2,1) tree
    st, qp, _ = kvc.append_tokens(st, tree_toks, spec_depth=sd)
    # siblings share positions; length untouched by speculative entries
    np.testing.assert_array_equal(qp, [[3, 3, 4, 4], [3, 3, 4, 4]])
    np.testing.assert_array_equal(st.length, [3, 3])
    keep = jnp.array([[True, False, True, False],
                      [False, True, False, True]])
    st = kvc.resolve_tree(st, 4, keep, jnp.array([2, 2], jnp.int32))
    np.testing.assert_array_equal(st.length, [5, 5])
    np.testing.assert_array_equal(
        np.asarray(st.mask[:, 3:7]),
        [[True, False, True, False], [False, True, False, True]])


# ---------------------------------------------------------------------------
# End-to-end: bit-equality + acceptance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 1), (1, 1, 1, 1)])
def test_branch1_tree_bit_identical_to_linear(pool, reference, shape):
    prompt, plens, ref = reference
    lin = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                      fixed_chain=("m68", "m7b"), fixed_window=len(shape)
                      ).generate(prompt, plens, 14, request_id="lin")
    tr = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                     fixed_chain=("m68", "m7b"), fixed_tree=shape
                     ).generate(prompt, plens, 14, request_id="tr")
    for b in range(3):
        np.testing.assert_array_equal(tr.generated[b], ref.generated[b])
        np.testing.assert_array_equal(tr.generated[b], lin.generated[b])


@pytest.mark.parametrize("chain,shape", [
    (("m68", "m7b"), (2, 2, 1)),
    (("m68", "m7b"), (3, 1, 1)),
    (("m68", "m1b", "m7b"), (2, 1, 1)),   # per-level pruning
])
def test_multibranch_tree_bit_identical(pool, reference, chain, shape):
    prompt, plens, ref = reference
    out = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                      fixed_chain=chain, fixed_tree=shape
                      ).generate(prompt, plens, 14, request_id="mb")
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])


def test_tree_accepts_at_least_linear(pool, reference):
    """Equal-depth A/B on the same seed: the drafted tree contains the
    linear top-1 chain as a sub-path, so per cycle it can only accept at
    least as much; over a whole generation that shows up as <= steps and
    >= mean accepted length."""
    prompt, plens, _ = reference
    lin = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                      fixed_chain=("m68", "m7b"), fixed_window=3
                      ).generate(prompt, plens, 14, request_id="l")
    tr = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                     fixed_chain=("m68", "m7b"), fixed_tree=(2, 2, 1)
                     ).generate(prompt, plens, 14, request_id="t")
    assert tr.steps <= lin.steps
    assert (np.mean(tr.acceptance_lengths)
            >= np.mean(lin.acceptance_lengths) - 1e-9)


def test_tree_adaptive_scheduler_equivalence(pool, reference):
    """Tree shapes join the adaptive search space without breaking the
    output-quality guarantee, and the scheduler's table prices them."""
    prompt, plens, ref = reference
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=True,
                    tree_shapes=((2, 1, 1), (2, 2, 1)))
    out = r.generate(prompt, plens, 14, request_id="ad")
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])
    choice = r.scheduler.get_optimal_chain()
    trees_priced = [tr for (_, _, tr) in choice.table if tr is not None]
    assert trees_priced, "no tree candidates in the scheduler table"


def test_tree_twin_models_accept_full_depth():
    """Greedy twin draft==target accepts the whole winning path + bonus;
    sampling twins accept the first sibling surely (p == q), so both modes
    must commit depth+1 per cycle."""
    p = ModelPool()
    cfg = ModelConfig(name="twin-a", arch_type="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=61, dtype=jnp.float32)
    lm = LanguageModel(cfg)
    params, axes = lm.init(jax.random.PRNGKey(5))
    p.register(cfg, params=params, param_axes=axes)
    p.register(dc.replace(cfg, name="twin-b"), params=params,
               param_axes=axes)
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(3),
                                         (2, 6), 0, 61))
    plens = np.array([6, 6])
    g = ChainRouter(p, "twin-b", greedy=True, adaptive=False,
                    fixed_chain=("twin-a", "twin-b"), fixed_tree=(2, 1, 1)
                    ).generate(prompt, plens, 12, request_id="g")
    assert np.mean(g.acceptance_lengths) >= 3.9       # D + bonus = 4
    s = ChainRouter(p, "twin-b", greedy=False, adaptive=False,
                    fixed_chain=("twin-a", "twin-b"), fixed_tree=(2, 2, 1)
                    ).generate(prompt, plens, 12, request_id="s")
    assert np.mean(s.acceptance_lengths) >= 3.9
    for out in (g, s):
        for gen in out.generated:
            assert ((gen >= 0) & (gen < 61)).all()


def test_tree_rejected_for_recurrent_archs(pool):
    cfg = ModelConfig(name="ssm-x", arch_type="ssm", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=61, dtype=jnp.float32)
    assert not cfg.supports_tree
    with pytest.raises(AssertionError):
        ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=("m7b",), fixed_tree=(2, 1))
