"""The paper's §5 Output Quality check: under greedy decoding, SpecRouter's
committed stream is BIT-IDENTICAL to target-only autoregressive decoding —
for any chain depth, window, batch, and with the adaptive scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool, Placement
from repro.models import ModelConfig
from repro.models.model import LanguageModel

pytestmark = pytest.mark.slow   # full bit-equality sweep, ~2 min on CPU


def build_pool(mesh=None):
    """The standard 3-model test pool; ``mesh`` places it (target
    tensor-parallel, drafts replicated — the serving default)."""
    p = ModelPool(placement=Placement.from_spec(mesh)
                  if mesh is not None else None)
    for (n, L, d, s) in [("m68", 2, 32, 1), ("m1b", 3, 48, 2),
                         ("m7b", 4, 64, 3)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=61, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    if not p.placement.is_trivial:
        p.placement.auto_assign(p.capability(), "m7b")
    return p


@pytest.fixture(scope="module")
def pool():
    return build_pool()


@pytest.fixture(scope="module")
def reference(pool):
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                         (3, 7), 0, 61))
    plens = np.array([7, 5, 6])
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=("m7b",), fixed_window=1)
    ref = r.generate(prompt, plens, 14, request_id="ref")
    return prompt, plens, ref


@pytest.mark.parametrize("chain,window", [
    (("m68", "m7b"), 2),
    (("m68", "m7b"), 4),
    (("m1b", "m7b"), 4),
    (("m68", "m1b", "m7b"), 3),
    (("m68", "m1b", "m7b"), 6),
])
def test_fixed_chain_equivalence(pool, reference, chain, window):
    prompt, plens, ref = reference
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=chain, fixed_window=window)
    out = r.generate(prompt, plens, 14, request_id="t")
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])


def test_adaptive_equivalence(pool, reference):
    prompt, plens, ref = reference
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=True)
    out = r.generate(prompt, plens, 14, request_id="a")
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])
    assert len(set(c for c, _ in out.chain_history)) >= 1


def test_eos_early_stop(pool):
    """Rows stopping at EOS must truncate exactly where target-only does."""
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(9),
                                         (2, 6), 0, 61))
    plens = np.array([6, 4])
    kw = dict(greedy=True, adaptive=False, eos_token=2)
    ref = ChainRouter(pool, "m7b", fixed_chain=("m7b",), fixed_window=1,
                      **kw).generate(prompt, plens, 20, request_id="r")
    out = ChainRouter(pool, "m7b", fixed_chain=("m68", "m7b"),
                      fixed_window=4, **kw).generate(prompt, plens, 20,
                                                     request_id="s")
    for b in range(2):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])


@pytest.mark.parametrize("mode", ["linear", "tree"])
def test_fused_equivalence(pool, reference, mode):
    """Device-resident fused cycles: greedy output bit-exact vs the
    per-op path (fused=False) and vs target-only, for linear and tree
    groups — with the profiling-cycle interleave active."""
    prompt, plens, ref = reference
    kw = dict(greedy=True, adaptive=False, fixed_chain=("m68", "m7b"))
    if mode == "tree":
        kw["fixed_tree"] = "2x1x1"
    else:
        kw["fixed_window"] = 4
    unf = ChainRouter(pool, "m7b", fused=False, **kw).generate(
        prompt, plens, 14, request_id=f"u{mode}")
    fus = ChainRouter(pool, "m7b", fused=True, profile_every=5,
                      **kw).generate(prompt, plens, 14,
                                     request_id=f"f{mode}")
    for b in range(3):
        np.testing.assert_array_equal(fus.generated[b], unf.generated[b])
        np.testing.assert_array_equal(fus.generated[b], ref.generated[b])


@pytest.mark.parametrize("mode", ["linear", "tree"])
@pytest.mark.parametrize("greedy", [True, False])
def test_mesh_1x1_bit_identical(pool, reference, mode, greedy):
    """The placement refactor's correctness anchor: a pool placed on a
    DEGENERATE 1x1 mesh (device_put with NamedShardings, placement-
    qualified profiling keys, the whole placement path active) produces
    BIT-identical output to the unmeshed pool — greedy and sampling,
    linear and tree, fused and per-op."""
    prompt, plens, _ = reference
    meshed = build_pool("1x1")
    assert not meshed.placement.is_trivial
    kw = dict(adaptive=False, fixed_chain=("m68", "m7b"))
    if mode == "tree":
        kw["fixed_tree"] = "2x1x1"
    else:
        kw["fixed_window"] = 4
    if greedy:
        kw["greedy"] = True
    else:
        kw.update(greedy=False, temperature=1.0, seed=11)
    for fused in (False, True):
        fkw = dict(kw, fused=fused)
        if fused:
            fkw["profile_every"] = 5
        ref = ChainRouter(pool, "m7b", **fkw).generate(
            prompt, plens, 14, request_id="um")
        out = ChainRouter(meshed, "m7b", **fkw).generate(
            prompt, plens, 14, request_id="mm")
        for b in range(3):
            np.testing.assert_array_equal(out.generated[b],
                                          ref.generated[b])


@pytest.mark.mesh
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 spawned devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_mesh_2x4_greedy_equivalence(pool, reference):
    """On a REAL 2x4 mesh (tensor-parallel target, replicated draft) the
    greedy committed stream still equals target-only — collectives change
    the lowering, not the tokens."""
    prompt, plens, ref = reference
    meshed = build_pool("2x4")
    out = ChainRouter(meshed, "m7b", greedy=True, adaptive=False,
                      fixed_chain=("m68", "m7b"), fixed_window=4,
                      fused=True, profile_every=5).generate(
                          prompt, plens, 14, request_id="m24")
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])


def test_speculation_actually_accepts():
    """A draft with IDENTICAL weights to the target must accept everything
    under greedy (sanity that acceptance accounting isn't trivially zero).
    Note: chains never repeat a model NAME (states are keyed by model), so
    the twin is registered as a separate pool entry."""
    p = ModelPool()
    cfg = ModelConfig(name="twin-a", arch_type="dense", num_layers=2,
                      d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
                      vocab_size=61, dtype=jnp.float32)
    lm = LanguageModel(cfg)
    params, axes = lm.init(jax.random.PRNGKey(5))
    p.register(cfg, params=params, param_axes=axes)
    import dataclasses as dc
    cfg_b = dc.replace(cfg, name="twin-b")
    p.register(cfg_b, params=params, param_axes=axes)

    prompt = np.array(jax.random.randint(jax.random.PRNGKey(3),
                                         (2, 6), 0, 61))
    plens = np.array([6, 6])
    r = ChainRouter(p, "twin-b", greedy=True, adaptive=False,
                    fixed_chain=("twin-a", "twin-b"), fixed_window=4)
    out = r.generate(prompt, plens, 12, request_id="x")
    assert np.mean(out.acceptance_lengths) >= 4.9   # W accepted + bonus
