"""Per-architecture smoke tests (assignment requirement f): a REDUCED
variant of each assigned family runs one forward/train step on CPU with
shape + finiteness assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models.model import LanguageModel

pytestmark = pytest.mark.slow   # one forward per assigned arch, ~90 s on CPU


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_decode(arch, key):
    cfg = get_smoke_config(arch)
    lm = LanguageModel(cfg)
    params, axes = lm.init(key)
    B, Tp = 2, 6
    state, _ = lm.make_state(B, 48,
                             with_snaps=cfg.arch_type in ("ssm", "hybrid"))
    toks = jax.random.randint(key, (B, Tp), 0, cfg.vocab_size)
    extras = lm.extras_for(B, key)
    logits, state = lm.prefill(params, state, toks, logits_mode="last",
                               **extras)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one decode step (serve_step shape)
    t2 = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    lg, state = lm.decode(params, state, t2, **extras)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # multi-token verify-style block + rollback
    t3 = jax.random.randint(key, (B, 4), 0, cfg.vocab_size)
    lg3, state = lm.decode(params, state, t3, **extras)
    assert bool(jnp.all(jnp.isfinite(lg3)))
    st2 = lm.rollback(state, jnp.array([2, 3]))
    np.testing.assert_array_equal(np.asarray(st2.length),
                                  np.asarray(state.length) - [2, 3])


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch, key):
    cfg = get_smoke_config(arch)
    lm = LanguageModel(cfg)
    params, _ = lm.init(key)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extras = lm.extras_for(B, key)

    def loss_fn(p):
        out = lm.train_logits(p, toks, remat=False, **extras)
        logits, aux = out if lm.has_aux_loss() else (out, 0.0)
        tgt = jnp.roll(toks, -1, axis=1)
        ll = jnp.take_along_axis(
            jax.nn.log_softmax(logits.astype(jnp.float32), -1),
            tgt[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll[:, :-1]) + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
