"""Paged KV cache (per-slot block tables + block pool): state invariants,
forward/rollback/resolve parity with the contiguous layout, the paged
flash-decode kernel vs its jnp oracle, and the headline churn regression —
one long-lived slot plus admission churn must run with ZERO defragment /
reprefill escapes while staying bit-identical to target-only decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool
from repro.core.state_manager import StateManager
from repro.kernels import ops, ref
from repro.models import ModelConfig
from repro.models import kv_cache as kvc
from repro.models.model import LanguageModel

pytestmark = pytest.mark.slow   # churn regression + kernel parity, ~80 s on CPU


def tiny_cfg(**kw):
    d = dict(name="t", arch_type="dense", num_layers=2, d_model=32,
             num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=41,
             dtype=jnp.float32)
    d.update(kw)
    return ModelConfig(**d)


@pytest.fixture(scope="module")
def pool():
    p = ModelPool()
    for (n, L, d, s) in [("s", 2, 32, 1), ("t", 3, 48, 2)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=64, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    return p


def _pool_invariant(st: kvc.PagedModelState):
    """Allocated table entries + free-stack prefix partition the pool."""
    tab = np.asarray(st.block_table)
    nb = np.asarray(st.num_blocks)
    owned = [int(tab[b, j]) for b in range(tab.shape[0])
             for j in range(int(nb[b]))]
    assert all(x >= 0 for x in owned)
    free = np.asarray(st.free_stack)[:int(st.free_top)].tolist()
    assert sorted(owned + free) == list(range(st.pool_blocks))


def _stream(st, b):
    order = np.argsort(np.where(st.mask[b], st.pos_buf[b], 1 << 30))
    return np.asarray(st.token_buf[b])[order][:int(st.length[b])]


# ---------------------------------------------------------------------------
# state-level invariants
# ---------------------------------------------------------------------------
def test_paged_append_rollback_stream_consistency():
    """Interleaved appends (with masked no-op rows) and divergent rollbacks
    keep each row's logical stream equal to a plain-Python reference, with
    the block pool always exactly partitioned."""
    rng = np.random.default_rng(0)
    st = kvc.make_paged_state(3, 64, {}, block_size=8)
    refs = [[], [], []]
    tok = 1
    for step in range(12):
        T = int(rng.integers(1, 5))
        valid = rng.random((3, T)) < 0.8
        toks = np.arange(tok, tok + 3 * T).reshape(3, T).astype(np.int32)
        tok += 3 * T
        st, _, _ = kvc.append_tokens(st, jnp.asarray(toks),
                                     jnp.asarray(valid))
        for b in range(3):
            refs[b].extend(toks[b, valid[b]].tolist())
        r = [int(rng.integers(0, min(3, len(refs[b])) + 1)) for b in range(3)]
        st = kvc.rollback(st, jnp.asarray(r))
        for b in range(3):
            if r[b]:
                del refs[b][-r[b]:]
        _pool_invariant(st)
        # per-row reclaim: linear rollback leaves NO holes at all
        assert float(kvc.fragmentation(st)) == 0.0
    for b in range(3):
        np.testing.assert_array_equal(_stream(st, b), refs[b])
        assert int(st.write_ptr[b]) == len(refs[b])


def test_paged_free_rows_returns_blocks_o1():
    """Retiring a row pushes all its blocks back; repeated admit/retire
    cycles never grow pool usage (the contiguous shared pointer grows by
    O(appended) per admission instead)."""
    st = kvc.make_paged_state(2, 64, {}, block_size=8)
    # long-lived row 0
    st, _, _ = kvc.append_tokens(st, jnp.arange(40).reshape(2, 20).astype(
        jnp.int32), jnp.asarray([[True] * 20, [False] * 20]))
    baseline = int(kvc.blocks_in_use(st))
    for i in range(10):
        toks = jnp.full((2, 12), i + 1, jnp.int32)
        st, _, _ = kvc.append_tokens(
            st, toks, jnp.asarray([[False] * 12, [True] * 12]))
        st = kvc.free_rows(st, np.array([False, True]))
        _pool_invariant(st)
        assert int(kvc.blocks_in_use(st)) == baseline   # no churn leak
        assert int(st.num_blocks[1]) == 0
        assert int(st.write_ptr[1]) == 0
    np.testing.assert_array_equal(_stream(st, 0), np.arange(20))


def test_paged_alloc_exhaustion_keeps_accounting_honest():
    """Pool underflow must not mint phantom blocks: num_blocks counts only
    pops that succeeded, so the host-side block accounting still sees the
    shortfall and the capacity guard can rebuild instead of letting writes
    silently drop."""
    st = kvc.make_paged_state(2, 64, {}, block_size=8, pool_blocks=3)
    st, _, _ = kvc.append_tokens(st, jnp.zeros((2, 16), jnp.int32))
    assert int(st.free_top) == 0
    assert int(jnp.sum(st.num_blocks)) == 3      # 4 were needed, 3 existed
    _pool_invariant(st)
    # the guard's arithmetic (ChainRouter._ensure_capacity) sees the hole
    wp, nb = np.asarray(st.write_ptr), np.asarray(st.num_blocks)
    shortfall = np.maximum(-(-(wp + 1) // st.block_size) - nb, 0)
    assert shortfall.sum() > 0


def test_paged_resolve_tree_matches_contiguous():
    """Settling a speculative tree block (winning path kept, dead branches
    masked) leaves the same logical stream in both layouts."""
    def run(paged):
        st = (kvc.make_paged_state(2, 64, {}, block_size=8) if paged
              else kvc.make_state(2, 64, {}))
        st, _, _ = kvc.append_tokens(
            st, jnp.arange(10).reshape(2, 5).astype(jnp.int32))
        # 6-node tree block: depths 0,0,1,1,2,2; row0 keeps path [0,2,4],
        # row1 keeps [1,3] (depth-2 node rejected)
        depth = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
        nodes = jnp.asarray([[10, 11, 12, 13, 14, 15],
                             [20, 21, 22, 23, 24, 25]], jnp.int32)
        st, _, _ = kvc.append_tokens(st, nodes, spec_depth=depth)
        keep = jnp.asarray([[1, 0, 1, 0, 1, 0],
                            [0, 1, 0, 1, 0, 0]], bool)
        st = kvc.resolve_tree(st, 6, keep, jnp.asarray([3, 2], jnp.int32),
                              active=jnp.asarray([True, True]))
        return st
    for paged in (False, True):
        st = run(paged)
        np.testing.assert_array_equal(_stream(st, 0),
                                      [0, 1, 2, 3, 4, 10, 12, 14])
        np.testing.assert_array_equal(_stream(st, 1),
                                      [5, 6, 7, 8, 9, 21, 23])
        if paged:
            _pool_invariant(st)


def test_paged_resolve_tree_inactive_row_untouched():
    """A row that sat the tree cycle out must keep its committed trailing
    slots — the paged resolver is gated by ``active``."""
    st = kvc.make_paged_state(2, 64, {}, block_size=8)
    st, _, _ = kvc.append_tokens(
        st, jnp.arange(12).reshape(2, 6).astype(jnp.int32))
    depth = jnp.asarray([0, 1], jnp.int32)
    active = jnp.asarray([True, False])
    st, _, _ = kvc.append_tokens(
        st, jnp.asarray([[30, 31], [0, 0]], jnp.int32),
        valid=jnp.broadcast_to(active[:, None], (2, 2)), spec_depth=depth)
    st = kvc.resolve_tree(st, 2, jnp.asarray([[1, 1], [0, 0]], bool),
                          jnp.asarray([2, 0], jnp.int32), active=active)
    np.testing.assert_array_equal(_stream(st, 0), [0, 1, 2, 3, 4, 5, 30, 31])
    np.testing.assert_array_equal(_stream(st, 1), [6, 7, 8, 9, 10, 11])


# ---------------------------------------------------------------------------
# model-level parity
# ---------------------------------------------------------------------------
def test_paged_rollback_equals_recompute():
    """Decode, divergent rollback, decode again == decoding the truncated
    stream from scratch — in the paged layout."""
    cfg = tiny_cfg()
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.PRNGKey(0))
    base = jnp.array([[5, 6, 7], [8, 9, 10]], jnp.int32)
    extra = jnp.array([[11, 12, 13, 14], [15, 16, 17, 18]], jnp.int32)
    nxt = jnp.array([[21], [23]], jnp.int32)

    st1, _ = lm.make_state(2, 32, paged=True, block_size=8)
    _, st1 = lm.prefill(params, st1, base)
    _, st1 = lm.decode(params, st1, extra)
    st1 = lm.rollback(st1, jnp.array([1, 3]))
    lg1, _ = lm.decode(params, st1, nxt)

    st2, _ = lm.make_state(2, 32, paged=True, block_size=8)
    _, st2 = lm.prefill(params, st2, base)
    _, st2 = lm.decode(params, st2, extra[:, :3],
                       valid=jnp.asarray([[True] * 3,
                                          [True, False, False]]))
    lg2, _ = lm.decode(params, st2, nxt)
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))


def test_paged_forward_matches_contiguous():
    """Same prefill/decode through both layouts gives identical logits
    (float32 — both programs compute the identical masked attention)."""
    cfg = tiny_cfg()
    lm = LanguageModel(cfg)
    params, _ = lm.init(jax.random.PRNGKey(1))
    base = jnp.array([[3, 4, 5], [6, 7, 8]], jnp.int32)
    steps = [jnp.array([[9, 10], [11, 12]], jnp.int32),
             jnp.array([[13], [14]], jnp.int32)]

    def run(paged):
        st, _ = lm.make_state(2, 32, paged=paged, block_size=8)
        outs = []
        lg, st = lm.prefill(params, st, base)
        outs.append(lg)
        for t in steps:
            lg, st = lm.decode(params, st, t)
            outs.append(lg)
        return [np.asarray(o) for o in outs]

    for a, b in zip(run(False), run(True)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# paged flash-decode kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T", [1, 5])
def test_paged_attention_kernel_matches_ref(T):
    rng = np.random.default_rng(3)
    P, bs, Hkv, D, B, R, g = 12, 8, 2, 24, 3, 3, 3
    H, S = Hkv * g, 3 * 8
    k_flat = jnp.asarray(rng.normal(size=(P * bs, Hkv, D)).astype(np.float32))
    v_flat = jnp.asarray(rng.normal(size=(P * bs, Hkv, D)).astype(np.float32))
    tbl = np.full((B, R), -1, np.int32)      # includes unallocated blocks
    used = rng.permutation(P)[:7]
    tbl[0, :3] = used[:3]
    tbl[1, :2] = used[3:5]
    tbl[2, :2] = used[5:7]
    q = jnp.asarray(rng.normal(size=(B, T, H, D)).astype(np.float32))
    mask = np.zeros((B, T, S), bool)
    mask[0, :, :20] = True
    mask[1, :, :10] = True
    mask[2, :, :13] = True
    if T > 1:                                 # ragged per-query (tree) rows
        mask[0, 1, 15:20] = False
        mask[2, 3, :] = False                 # fully-masked query row
    m = jnp.asarray(mask)
    kp = k_flat.reshape(P, bs, Hkv, D)
    vp = v_flat.reshape(P, bs, Hkv, D)
    want = np.asarray(ref.paged_attention_ref(q, kp, vp, jnp.asarray(tbl), m))
    got = np.asarray(ops.paged_decode_attention(
        q, k_flat, v_flat, jnp.asarray(tbl), m, bs))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    if T > 1:
        assert np.all(got[2, 3] == 0)        # fully masked -> zeros, no NaN


def test_paged_kernel_single_token_equals_tree_t1():
    """The T=1 paged call reproduces the gathered single-token decode —
    one kernel subsumes both serving cases."""
    rng = np.random.default_rng(4)
    P, bs, Hkv, D, B, R = 6, 8, 2, 16, 2, 3
    H, S = 4, R * bs
    k_flat = jnp.asarray(rng.normal(size=(P * bs, Hkv, D)).astype(np.float32))
    v_flat = jnp.asarray(rng.normal(size=(P * bs, Hkv, D)).astype(np.float32))
    tbl = jnp.asarray([[0, 2, -1], [1, -1, -1]], jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)).astype(np.float32))
    mask = np.zeros((B, 1, S), bool)
    mask[0, :, :11] = True
    mask[1, :, :7] = True
    got = np.asarray(ops.paged_decode_attention(
        q, k_flat, v_flat, tbl, jnp.asarray(mask), bs))
    # oracle: gather the rows' views and run the plain decode reference
    flat = np.asarray(kvc.paged_gather(
        k_flat, _view_idx(np.asarray(tbl), bs, S)))
    flatv = np.asarray(kvc.paged_gather(
        v_flat, _view_idx(np.asarray(tbl), bs, S)))
    want = np.asarray(ref.masked_decode_attention_ref(
        q[:, 0], jnp.asarray(flat), jnp.asarray(flatv),
        jnp.asarray(mask[:, 0])))
    np.testing.assert_allclose(got[:, 0], want, rtol=2e-5, atol=2e-5)


def _view_idx(tbl, bs, S):
    s = np.arange(S)
    pid = tbl[:, s // bs]
    return jnp.asarray(np.maximum(pid, 0) * bs + s % bs)


# ---------------------------------------------------------------------------
# headline churn regression
# ---------------------------------------------------------------------------
def test_churn_zero_defrag_reprefill_and_bit_exact(pool):
    """One long-lived slot plus repeated admit/retire churn in the other:
    paged mode must never hit the defragment or reprefill escape hatches
    (the contiguous shared pointer burns capacity at O(cycles) and does),
    and every stream must stay bit-identical to target-only decoding."""
    rng = np.random.default_rng(7)
    long_prompt = rng.integers(1, 64, size=8).astype(np.int64)
    shorts = [rng.integers(1, 64, size=6).astype(np.int64) for _ in range(8)]
    # sized so the long request ALONE fits a row comfortably (~70 slots
    # peak) but the contiguous shared pointer — which permanently leaks
    # each retired admission's slots once the long row appends above them —
    # exhausts and must defragment/rebuild
    max_len = 128

    def churn(paged):
        router = ChainRouter(pool, "t", adaptive=False,
                             fixed_chain=("s", "t"), fixed_window=3,
                             paged=paged)
        sess = router.start_session(2, max_len, session_id="churn")
        sess.admit(0, long_prompt, 40)
        outs = []
        for sp in shorts:
            sess.admit(1, sp, 4)
            while sess.active[1]:
                sess.run_cycle()
            outs.append(sess.retire(1))
        while sess.active[0]:
            sess.run_cycle()
        st = router.states.get(StateManager.key("t", "churn"))
        is_paged = isinstance(st, kvc.PagedModelState)
        long_out = sess.retire(0)
        sess.close()
        counters = dict(router.profiler.counters)
        return long_out, outs, counters, is_paged

    long_p, shorts_p, counters_p, was_paged = churn(True)
    # THE acceptance criterion: zero escape hatches in paged mode
    bad = {k: v for k, v in counters_p.items()
           if k.startswith("defrag.") or k.startswith("reprefill.")}
    assert not bad, f"paged churn tripped capacity escapes: {bad}"

    assert was_paged          # the session really ran on the paged layout

    # contiguous A/B on the SAME sizing: the shared write pointer must hit
    # the escape hatches (this is the bug being fixed)
    long_c, shorts_c, counters_c, was_paged_c = churn(False)
    assert not was_paged_c
    assert any(k.startswith("defrag.") or k.startswith("reprefill.")
               for k in counters_c), (
        "contiguous baseline unexpectedly survived churn — "
        "tighten the workload so the regression test stays sharp")

    # bit-exact greedy parity: paged churn output == target-only reference
    ref_router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("t",),
                             fixed_window=1, paged=True)
    ref_long = ref_router.generate(long_prompt[None, :], np.array([8]), 40,
                                   request_id="ref-long")
    np.testing.assert_array_equal(long_p, ref_long.generated[0])
    for i, sp in enumerate(shorts):
        r = ref_router.generate(sp[None, :], np.array([6]), 4,
                                request_id=f"ref-s{i}")
        np.testing.assert_array_equal(shorts_p[i], r.generated[0])
    # and the contiguous run decodes the same streams (same greedy argmax)
    np.testing.assert_array_equal(long_p, long_c)
    for a, b in zip(shorts_p, shorts_c):
        np.testing.assert_array_equal(a, b)


def test_paged_session_blocks_bounded_under_churn(pool):
    """Block accounting stays bounded: pool usage after each retire returns
    to the long-lived row's own footprint (no cross-slot leak)."""
    rng = np.random.default_rng(8)
    router = ChainRouter(pool, "t", adaptive=False, fixed_chain=("s", "t"),
                         fixed_window=3, paged=True)
    sess = router.start_session(2, 192, session_id="bounded")
    sess.admit(0, rng.integers(1, 64, size=8).astype(np.int64), 24)
    usage = []
    for i in range(4):
        sess.admit(1, rng.integers(1, 64, size=6).astype(np.int64), 4)
        while sess.active[1]:
            sess.run_cycle()
        sess.retire(1)
        st = router.states.get(StateManager.key("t", "bounded"))
        assert isinstance(st, kvc.PagedModelState)
        assert int(st.num_blocks[1]) == 0
        usage.append(int(kvc.blocks_in_use(st)))
        _pool_invariant(st)
    # the retired slot's blocks always come back; usage tracks only the
    # long row's (monotone but bounded by its own footprint) growth
    assert usage[-1] <= usage[0] + (24 // st.block_size + 2)
    sess.close()
