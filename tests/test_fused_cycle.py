"""Device-resident fused cycles: the whole draft→multi-level-verify→commit
loop runs as ONE jitted program per (chain, window | tree) group, session
buffers live on device, and one small summary crosses to host per cycle.

Pinned here:
  * greedy bit-equality: fused == per-op == target-only, linear (2- and
    3-deep) and tree groups;
  * session lifecycle on the fused path — mid-cycle EOS termination,
    retire-then-readmit into a fused group;
  * the profiling-cycle interleave: scheduler T_i EMAs keep updating
    while fused output stays bit-exact;
  * strictly fewer host syncs per cycle than the per-op path;
  * the sampling-without-rng footgun raises instead of silently reusing
    PRNGKey(0) every cycle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ChainRouter, ModelPool, Placement
from repro.core.executor import DraftRequest
from repro.models import ModelConfig
from repro.models.model import LanguageModel


def build_pool(mesh=None):
    p = ModelPool(placement=Placement.from_spec(mesh)
                  if mesh is not None else None)
    for (n, L, d, s) in [("m68", 2, 32, 1), ("m1b", 3, 48, 2),
                         ("m7b", 4, 64, 3)]:
        cfg = ModelConfig(name=n, arch_type="dense", num_layers=L,
                          d_model=d, num_heads=4, num_kv_heads=2,
                          d_ff=2 * d, vocab_size=61, dtype=jnp.float32)
        lm = LanguageModel(cfg)
        params, axes = lm.init(jax.random.PRNGKey(s))
        p.register(cfg, params=params, param_axes=axes)
    if not p.placement.is_trivial:
        p.placement.auto_assign(p.capability(), "m7b")
    return p


@pytest.fixture(scope="module")
def pool():
    return build_pool()


@pytest.fixture(scope="module")
def reference(pool):
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(0),
                                         (3, 7), 0, 61))
    plens = np.array([7, 5, 6])
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=("m7b",), fixed_window=1, fused=False)
    ref = r.generate(prompt, plens, 14, request_id="ref")
    return prompt, plens, ref


@pytest.mark.slow   # 4 router pairs x full jit compile, ~2 min on CPU
@pytest.mark.parametrize("chain,window,tree", [
    (("m68", "m7b"), 4, None),
    (("m68", "m1b", "m7b"), 3, None),
    (("m7b",), 1, None),
    (("m68", "m7b"), 3, "2x2x1"),
])
def test_fused_bit_exact(pool, reference, chain, window, tree):
    """Fused greedy output == per-op output == target-only, and the fused
    run takes the same number of cycles (it is the same cycle, relocated
    on device)."""
    prompt, plens, ref = reference
    kw = dict(greedy=True, adaptive=False, fixed_chain=chain)
    if tree is not None:
        kw["fixed_tree"] = tree
    else:
        kw["fixed_window"] = window
    unf = ChainRouter(pool, "m7b", fused=False, **kw)
    ru = unf.generate(prompt, plens, 14, request_id="u")
    fus = ChainRouter(pool, "m7b", fused=True, profile_every=4, **kw)
    rf = fus.generate(prompt, plens, 14, request_id="f")
    assert rf.steps == ru.steps
    for b in range(3):
        np.testing.assert_array_equal(rf.generated[b], ru.generated[b])
        np.testing.assert_array_equal(rf.generated[b], ref.generated[b])


def test_fused_fewer_host_syncs(pool, reference):
    """The fused path's host-sync count per cycle must be strictly below
    the per-op path on the same workload (the one-transfer-per-cycle
    contract; benchmarks/cycle_overhead.py asserts the same in CI)."""
    prompt, plens, _ = reference
    counts = {}
    for fused in (False, True):
        r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                        fixed_chain=("m68", "m1b", "m7b"), fixed_window=3,
                        fused=fused, profile_every=8)
        r.generate(prompt, plens, 14, request_id="w")
        s0 = r.profiler.counters["host_sync"]
        out = r.generate(prompt, plens, 14, request_id="x")
        counts[fused] = (r.profiler.counters["host_sync"] - s0) / out.steps
    assert counts[True] < counts[False]


def test_fused_eos_termination(pool):
    """Mid-cycle EOS with device-resident buffers: rows must truncate
    exactly where target-only does, deactivate in the device mirror, and
    survive the budget clamp ordering."""
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(9),
                                         (2, 6), 0, 61))
    plens = np.array([6, 4])
    kw = dict(greedy=True, adaptive=False, eos_token=2)
    ref = ChainRouter(pool, "m7b", fixed_chain=("m7b",), fixed_window=1,
                      fused=False, **kw).generate(prompt, plens, 20,
                                                  request_id="r")
    # profile_every high => every post-0 cycle (incl. the terminating one)
    # runs fused
    out = ChainRouter(pool, "m7b", fixed_chain=("m68", "m7b"),
                      fixed_window=4, fused=True, profile_every=1000,
                      **kw).generate(prompt, plens, 20, request_id="s")
    for b in range(2):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])


def test_fused_retire_then_readmit(pool, reference):
    """Session lifecycle on a fused group: retire a finished slot, admit a
    new request into it, keep cycling fused — every request bit-exact."""
    prompt, plens, _ = reference
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=("m68", "m7b"), fixed_window=4, fused=True,
                    profile_every=6)
    sess = r.start_session(2, 96, session_id="s")
    sess.admit(0, prompt[0, :plens[0]], 10)
    sess.admit(1, prompt[1, :plens[1]], 10)
    while sess.active.any():
        sess.run_cycle()
    out0, out1 = sess.retire(0), sess.retire(1)
    sess.admit(0, prompt[2, :plens[2]], 10)      # readmit into slot 0
    while sess.active.any():
        sess.run_cycle()
    out2 = sess.retire(0)
    sess.close()
    ref = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                      fixed_chain=("m7b",), fixed_window=1, fused=False
                      ).generate(prompt, plens, 10, request_id="rr")
    np.testing.assert_array_equal(out0, ref.generated[0])
    np.testing.assert_array_equal(out1, ref.generated[1])
    np.testing.assert_array_equal(out2, ref.generated[2])


def test_profiling_cycle_interleave_updates_t_i(pool, reference):
    """Fusing hides per-op timings, so every profile_every-th cycle runs
    the per-op path: the scheduler's decode1/verify EMAs must keep
    accumulating across a fused run while output stays bit-exact."""
    prompt, plens, ref = reference
    r = ChainRouter(pool, "m7b", greedy=True, adaptive=False,
                    fixed_chain=("m68", "m7b"), fixed_window=4, fused=True,
                    profile_every=3)
    out = r.generate(prompt, plens, 14, request_id="p")
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])
    # cycles 0, 3, 6, ... ran the per-op path: T_i evidence accumulates
    dec = r.profiler.emas[("decode1", "m68")]
    ver = [e for k, e in r.profiler.emas.items()
           if k[0] == "verify" and k[1] == "m7b" and e.count]
    assert dec.count >= out.steps // 3
    assert ver and sum(e.count for e in ver) >= out.steps // 3
    # and the scheduler reads a real measurement, not the cold default
    assert r.profiler.decode_time("m68", default=-1.0) > 0.0
    # fused cycles ran between the profiling cycles (not all per-op)
    assert r.profiler.emas[("fused_cycle", "m68+m7b")].count > 0


@pytest.mark.slow   # extra compile pair on the placed pool
@pytest.mark.parametrize("mesh", ["1x1"])
def test_fused_mesh_bit_exact(pool, reference, mesh):
    """The fused cycle built over a 1x1-PLACED pool (NamedSharding state
    buffers, level-boundary reshard closures compiled in) commits the
    exact same tokens as the unmeshed fused path, in the same number of
    cycles, with the same single host transfer per cycle."""
    prompt, plens, _ = reference
    meshed = build_pool(mesh)
    kw = dict(greedy=True, adaptive=False, fixed_chain=("m68", "m7b"),
              fixed_window=4, fused=True, profile_every=1000)
    ref = ChainRouter(pool, "m7b", **kw).generate(
        prompt, plens, 14, request_id="u")
    r = ChainRouter(meshed, "m7b", **kw)
    out = r.generate(prompt, plens, 14, request_id="m")
    assert out.steps == ref.steps
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])


@pytest.mark.mesh
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 spawned devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_fused_mesh_2x4_one_transfer_per_cycle(pool, reference):
    """On the 2x4 mesh the fused cycle still makes exactly ONE host
    transfer per cycle — the commit slab moves between chain levels via
    device-side collectives, never through the host — and commits the
    same greedy tokens as the unmeshed fused path."""
    prompt, plens, _ = reference
    meshed = build_pool("2x4")
    kw = dict(greedy=True, adaptive=False, fixed_chain=("m68", "m7b"),
              fixed_window=4, fused=True, profile_every=1000)
    ref = ChainRouter(pool, "m7b", **kw).generate(
        prompt, plens, 14, request_id="u")
    r = ChainRouter(meshed, "m7b", **kw)
    out = r.generate(prompt, plens, 14, request_id="m")
    for b in range(3):
        np.testing.assert_array_equal(out.generated[b], ref.generated[b])
    # steady-state transfer count: cycle 0 of a session is the per-op
    # profiling cycle (intentional syncs); every fused cycle after it
    # must make exactly one host transfer
    sess = r.start_session(2, 96, session_id="m24")
    sess.admit(0, prompt[0, :plens[0]], 10)
    sess.admit(1, prompt[1, :plens[1]], 10)
    sess.run_cycle()
    steps, s0 = 0, r.profiler.counters["host_sync"]
    while sess.active.any() and steps < 6:
        sess.run_cycle()
        steps += 1
    assert steps > 0
    assert r.profiler.counters["host_sync"] - s0 == steps
    sess.close()


def test_sampling_without_rng_raises(pool):
    """The PRNGKey(0)-every-cycle fallback is gone: a sampling request
    without an rng must raise instead of silently repeating draws."""
    r = ChainRouter(pool, "m7b", greedy=False, adaptive=False,
                    fixed_chain=("m68", "m7b"), fixed_window=2)
    prompt = np.array([[1, 2, 3, 4]])
    sess = r.start_session(1, 64, session_id="q")
    sess.admit(0, prompt[0], 4)
    with pytest.raises(ValueError, match="sampling requested without"):
        r.executor.draft(DraftRequest(
            model="m68", request_id="q",
            prefix_tokens=np.array([[4]], np.int32),
            prefix_valid=np.array([[True]]),
            window=2, active=np.array([True]), greedy=False, rng=None))
    sess.close()


@pytest.mark.slow   # second full compile pair in sampling mode
def test_fused_sampling_matches_per_op(pool):
    """Bonus guarantee: the fused program consumes the session RNG stream
    exactly as the per-op path (one key per chain position), so even
    SAMPLING output is bit-equal between the paths."""
    prompt = np.array(jax.random.randint(jax.random.PRNGKey(4),
                                         (2, 6), 0, 61))
    plens = np.array([6, 5])
    kw = dict(greedy=False, temperature=1.0, adaptive=False,
              fixed_chain=("m68", "m7b"), fixed_window=4, seed=11)
    a = ChainRouter(pool, "m7b", fused=False, **kw).generate(
        prompt, plens, 10, request_id="a")
    b = ChainRouter(pool, "m7b", fused=True, profile_every=3, **kw
                    ).generate(prompt, plens, 10, request_id="b")
    for i in range(2):
        np.testing.assert_array_equal(a.generated[i], b.generated[i])
