"""Direct conformance tests for the workload generators in
``data/workload.py`` — the open-loop request source every serving A/B
depends on: seeded determinism, lognormal length-profile sanity, Poisson
inter-arrival statistics, MMPP burst duty cycle, JSONL trace round-trip,
SLO resolution, and the ``streams_bit_exact`` A/B helper's unset-stream
guard."""
import numpy as np
import pytest

from repro.data import (DATASET_SLOS, CorpusConfig, Request,
                        SyntheticCorpus, load_trace, make_bursty_workload,
                        make_workload, resolve_slo, save_trace,
                        streams_bit_exact)
from repro.data.workload import DATASET_PROFILES


@pytest.fixture(scope="module")
def corpus():
    return SyntheticCorpus(CorpusConfig(vocab_size=64))


# ---------------------------------------------------------------------------
# seeded determinism
# ---------------------------------------------------------------------------
def _fingerprint(reqs):
    return [(r.request_id, round(r.arrival_s, 12), r.prompt.tolist(),
             r.max_new_tokens, r.ttft_slo_s, r.tpot_slo_s) for r in reqs]


def test_seeded_determinism_poisson(corpus):
    a = make_workload(corpus, "gsm8k", 4.0, 10.0, seed=7)
    b = make_workload(corpus, "gsm8k", 4.0, 10.0, seed=7)
    assert _fingerprint(a) == _fingerprint(b)
    c = make_workload(corpus, "gsm8k", 4.0, 10.0, seed=8)
    assert _fingerprint(a) != _fingerprint(c)


def test_seeded_determinism_bursty(corpus):
    kw = dict(rate_on_rps=8.0, duration_s=20.0, mean_on_s=1.0,
              mean_off_s=3.0, seed=5)
    a = make_bursty_workload(corpus, "gsm8k", **kw)
    b = make_bursty_workload(corpus, "gsm8k", **kw)
    assert _fingerprint(a) == _fingerprint(b)


# ---------------------------------------------------------------------------
# length profiles
# ---------------------------------------------------------------------------
def test_lognormal_profile_bounds_and_means(corpus):
    reqs = make_workload(corpus, "humaneval", 20.0, 40.0, seed=3,
                         scale=0.25, max_prompt=400, max_out=400)
    assert len(reqs) > 300
    plens = np.array([len(r.prompt) for r in reqs])
    olens = np.array([r.max_new_tokens for r in reqs])
    assert plens.min() >= 4 and plens.max() <= 400
    assert olens.min() >= 4 and olens.max() <= 400
    # with generous clip bounds the sample mean must sit near the
    # lognormal mean exp(mu + sigma^2/2) * scale (loose 2x band)
    pmu, psig, omu, osig = DATASET_PROFILES["humaneval"]
    want_p = np.exp(pmu + psig ** 2 / 2) * 0.25
    want_o = np.exp(omu + osig ** 2 / 2) * 0.25
    assert want_p / 2 < plens.mean() < want_p * 2
    assert want_o / 2 < olens.mean() < want_o * 2


def test_profile_clipping(corpus):
    reqs = make_workload(corpus, "mtbench", 10.0, 10.0, seed=1,
                         max_prompt=12, max_out=6)
    assert max(len(r.prompt) for r in reqs) <= 12
    assert max(r.max_new_tokens for r in reqs) <= 6


# ---------------------------------------------------------------------------
# arrival statistics
# ---------------------------------------------------------------------------
def test_poisson_interarrival_statistics(corpus):
    rate = 10.0
    reqs = make_workload(corpus, "gsm8k", rate, 100.0, seed=11)
    arr = np.array([r.arrival_s for r in reqs])
    assert np.all(np.diff(arr) >= 0) and arr.max() < 100.0
    gaps = np.diff(arr)
    # exponential inter-arrivals: mean 1/rate, CV 1 (loose 25% bands at
    # ~1000 samples)
    assert abs(gaps.mean() - 1.0 / rate) < 0.25 / rate
    cv = gaps.std() / gaps.mean()
    assert 0.75 < cv < 1.25


def test_mmpp_duty_cycle_and_burst_confinement(corpus):
    mean_on, mean_off = 1.0, 3.0
    reqs, states = make_bursty_workload(
        corpus, "gsm8k", rate_on_rps=20.0, duration_s=200.0,
        rate_off_rps=0.0, mean_on_s=mean_on, mean_off_s=mean_off,
        seed=13, return_states=True)
    # states tile [0, duration) without gaps and alternate on/off
    assert states[0][0] == 0.0
    for (s0, e0, on0), (s1, e1, on1) in zip(states, states[1:]):
        assert abs(e0 - s1) < 1e-9 and on0 != on1
    on_time = sum(e - s for s, e, on in states if on)
    total = sum(e - s for s, e, _ in states)
    # duty cycle ~ mean_on / (mean_on + mean_off) = 0.25 (loose band:
    # ~50 cycles of each state at duration 200)
    duty = on_time / total
    want = mean_on / (mean_on + mean_off)
    assert abs(duty - want) < 0.12
    # rate_off = 0: every arrival falls inside an ON interval
    on_iv = [(s, e) for s, e, on in states if on]
    for r in reqs:
        assert any(s <= r.arrival_s <= e for s, e in on_iv)
    # arrival volume ~ rate_on * on_time (loose 25% band)
    assert abs(len(reqs) - 20.0 * on_time) < 0.25 * 20.0 * on_time


def test_mmpp_off_rate_trickle(corpus):
    reqs, states = make_bursty_workload(
        corpus, "gsm8k", rate_on_rps=20.0, duration_s=120.0,
        rate_off_rps=1.0, mean_on_s=1.0, mean_off_s=3.0, seed=17,
        return_states=True)
    off_iv = [(s, e) for s, e, on in states if not on]
    n_off = sum(1 for r in reqs
                if any(s <= r.arrival_s <= e for s, e in off_iv))
    assert n_off > 0                      # the OFF state does trickle
    assert n_off < 0.5 * len(reqs)        # ...but bursts dominate


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------
def test_trace_round_trip(tmp_path, corpus):
    reqs = make_bursty_workload(corpus, "humaneval", rate_on_rps=5.0,
                                duration_s=10.0, seed=3, with_slo=True)
    assert reqs, "empty workload would vacuously pass"
    path = str(tmp_path / "trace.jsonl")
    save_trace(reqs, path)
    back = load_trace(path)
    assert _fingerprint(back) == _fingerprint(reqs)
    for a, b in zip(reqs, back):
        np.testing.assert_array_equal(a.prompt, b.prompt)
        assert b.prompt.dtype == np.int64
        # engine-filled fields are NOT replayed
        assert b.start_s < 0 and b.finish_s < 0 and not b.shed


def test_trace_slo_override(tmp_path, corpus):
    reqs = make_workload(corpus, "gsm8k", 5.0, 5.0, seed=2)
    path = str(tmp_path / "t.jsonl")
    save_trace(reqs, path)
    back = load_trace(path, ttft_slo=1.5, tpot_slo=0.25)
    assert all(r.ttft_slo_s == 1.5 and r.tpot_slo_s == 0.25 for r in back)


# ---------------------------------------------------------------------------
# SLO resolution + Request SLO semantics
# ---------------------------------------------------------------------------
def test_slo_defaults_and_overrides(corpus):
    # default: no SLO at all
    r0 = make_workload(corpus, "gsm8k", 5.0, 5.0, seed=1)[0]
    assert r0.ttft_slo_s is None and r0.tpot_slo_s is None
    assert r0.ttft_deadline_s == float("inf")
    # with_slo: per-dataset defaults
    r1 = make_workload(corpus, "gsm8k", 5.0, 5.0, seed=1,
                       with_slo=True)[0]
    assert (r1.ttft_slo_s, r1.tpot_slo_s) == DATASET_SLOS["gsm8k"]
    # explicit values override the dataset default per axis
    r2 = make_workload(corpus, "gsm8k", 5.0, 5.0, seed=1,
                       with_slo=True, ttft_slo=9.0)[0]
    assert r2.ttft_slo_s == 9.0
    assert r2.tpot_slo_s == DATASET_SLOS["gsm8k"][1]
    # an explicit SLO alone activates SLOs without with_slo
    assert resolve_slo("gsm8k", ttft_slo=3.0) == (3.0, None)
    assert resolve_slo("gsm8k") == (None, None)


def test_request_slo_met():
    base = dict(prompt=np.array([1, 2]), max_new_tokens=4,
                dataset="synthetic")
    # met: ttft = 2.0 - 1.0 = 1.0 <= 2.0, tpot = (4-2)/(4-1) ~ 0.67 <= 1.0
    r = Request("a", 1.0, ttft_slo_s=2.0, tpot_slo_s=1.0, start_s=1.0,
                first_token_s=2.0, finish_s=4.0, generated=4, **base)
    assert r.slo_met
    assert r.ttft_deadline_s == 3.0
    # TTFT blown
    late = Request("b", 1.0, ttft_slo_s=0.5, start_s=1.0,
                   first_token_s=2.0, finish_s=4.0, generated=4, **base)
    assert not late.slo_met
    # TPOT blown
    slow = Request("c", 1.0, tpot_slo_s=0.1, start_s=1.0,
                   first_token_s=2.0, finish_s=14.0, generated=4, **base)
    assert not slow.slo_met
    # shed / unfinished are always misses
    shed = Request("d", 1.0, shed=True, **base)
    assert not shed.slo_met
    unfin = Request("e", 1.0, **base)
    assert not unfin.slo_met
    # finished request with no SLO counts as met
    free = Request("f", 1.0, start_s=1.0, first_token_s=2.0,
                   finish_s=4.0, generated=4, **base)
    assert free.slo_met


# ---------------------------------------------------------------------------
# A/B bit-equality helper
# ---------------------------------------------------------------------------
def test_streams_bit_exact_guards():
    base = dict(prompt=np.array([1]), max_new_tokens=2, dataset="s")
    served = Request("a", 0.0, output_tokens=np.array([3, 4]), **base)
    # output_tokens defaults to None -> clear error, not a TypeError
    unset = Request("b", 0.0, **base)
    assert unset.output_tokens is None
    with pytest.raises(ValueError, match="no committed output stream"):
        streams_bit_exact([unset], [np.array([3, 4])])
    # shed requests are skipped (they never produced a stream)
    shed = Request("c", 0.0, shed=True, **base)
    assert streams_bit_exact([served, shed],
                             [np.array([3, 4]), np.array([9])])
    # mismatched stream -> False; mismatched population -> error
    assert not streams_bit_exact([served], [np.array([3, 5])])
    with pytest.raises(ValueError, match="mismatched populations"):
        streams_bit_exact([served], [])
