"""Substrate units: optimizer, checkpoint store, data pipeline, sharding
rules, profiler."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint import exists, load, save
from repro.core import PerformanceProfiler
from repro.data import CorpusConfig, SyntheticCorpus, make_workload
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule
from repro.sharding import RULES, spec_for, with_decode_rules


# ---------------------------------------------------------------------------
def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(params, grads, opt, lr=0.05,
                                   weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-6


def test_cosine_schedule_shape():
    import numpy as np
    s = [float(cosine_schedule(jnp.asarray(t), 1.0, 10, 100))
         for t in range(0, 100, 10)]
    assert s[0] == 0.0 and abs(s[1] - 1.0) < 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(s[1:], s[2:]))  # decreasing


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    p = str(tmp_path / "ck")
    save(p, tree, metadata={"x": 1})
    assert exists(p)
    got = load(p, tree)
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(np.asarray(got["b"]["c"], np.float32),
                                  np.asarray(tree["b"]["c"], np.float32))


# ---------------------------------------------------------------------------
def test_corpus_determinism_and_learnability():
    c1 = SyntheticCorpus(CorpusConfig(vocab_size=128, seed=5))
    c2 = SyntheticCorpus(CorpusConfig(vocab_size=128, seed=5))
    b1 = next(c1.batches(2, 32, seed=3))
    b2 = next(c2.batches(2, 32, seed=3))
    np.testing.assert_array_equal(b1, b2)
    assert b1.max() < 128 and b1.min() >= 0
    # low-entropy: bigram repetition should be far above uniform chance
    seq = c1.sample(np.random.default_rng(0), 4000)
    bigrams = set(zip(seq[:-1], seq[1:]))
    assert len(bigrams) < 0.2 * 128 * 128


def test_workload_poisson_and_profiles():
    c = SyntheticCorpus(CorpusConfig(vocab_size=64))
    reqs = make_workload(c, "gsm8k", rate_rps=5.0, duration_s=20.0, seed=1)
    assert len(reqs) > 50
    arr = np.array([r.arrival_s for r in reqs])
    assert np.all(np.diff(arr) >= 0)
    gaps = np.diff(arr)
    assert 0.05 < gaps.mean() < 0.6         # ~1/5 rps
    mt = make_workload(c, "mtbench", rate_rps=5.0, duration_s=20.0, seed=1)
    assert (np.mean([len(r.prompt) for r in mt])
            > np.mean([len(r.prompt) for r in reqs]))  # mtbench longer


# ---------------------------------------------------------------------------
def test_sharding_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # with axis size 1 everything degrades to replication
    assert spec_for(("batch", "seq"), (128, 4096), mesh, RULES) == P()


def test_sharding_rules_priority():
    # seq only takes the model axis if kv_heads cannot (decode rules)
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    r = with_decode_rules(RULES)
    spec1 = spec_for(("layers", "batch", "seq", "kv_heads", "head_dim"),
                     (40, 128, 32768, 16, 128), FakeMesh(), r)
    assert spec1 == P(None, "data", None, "model")
    spec2 = spec_for(("layers", "batch", "seq", "kv_heads", "head_dim"),
                     (40, 128, 32768, 20, 128), FakeMesh(), r)  # kv=20 ✗
    assert spec2 == P(None, "data", "model")


# ---------------------------------------------------------------------------
def test_profiler_verify_time_fallback():
    p = PerformanceProfiler()
    p.record("verify", "m", 0.1, block=5)
    # exact hit
    assert abs(p.verify_time("m", 5, 9.9) - 0.1) < 1e-9
    # nearest-block scaled fallback, not the default
    assert p.verify_time("m", 10, 9.9) != 9.9
    # unknown model -> default
    assert p.verify_time("zz", 5, 9.9) == 9.9
