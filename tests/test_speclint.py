"""speclint conformance tests.

Positive/negative fixture pairs per AST rule (path-suffix scoping means a
tmp tree like ``tmp/core/executor.py`` exercises the hot-path rules),
suppression and baseline semantics, CLI exit codes, the kernel/oracle
meta-rule against both fixtures and the real tree, and the Pallas bounds
checker against an injected out-of-bounds index map and the real kernels.
The jaxpr/HLO dynamic tiers (which jit a tiny pool) are marked slow.
"""
import json

import numpy as np
import pytest

from repro.analysis import ast_rules, meta_rules, pallas_bounds
from repro.analysis.findings import (
    Baseline,
    Finding,
    apply_suppressions,
    collect_suppressions,
)
from repro.analysis.speclint import main as speclint_main


def rules_of(findings):
    return [f.rule for f in findings]


def scan(path, source):
    return ast_rules.run_file(path, source)


# ---------------------------------------------------------------------------
# AST tier: positive / negative pairs per rule
# ---------------------------------------------------------------------------
class TestHostSyncRule:
    def test_device_get_in_hot_path_flagged(self):
        src = "import jax\n\ndef f(x):\n    return jax.device_get(x)\n"
        found = scan("src/repro/core/executor.py", src)
        assert rules_of(found) == ["host-sync"]
        assert found[0].line == 4

    def test_device_get_outside_hot_path_ok(self):
        src = "import jax\n\ndef f(x):\n    return jax.device_get(x)\n"
        assert scan("benchmarks/report.py", src) == []

    def test_item_in_models_flagged(self):
        src = "def f(x):\n    return x.item()\n"
        assert rules_of(scan("src/repro/models/ssm.py", src)) == ["host-sync"]

    def test_np_asarray_in_traced_scope_flagged(self):
        src = (
            "import jax\nimport numpy as np\n\n"
            "@jax.jit\ndef step(x):\n    return np.asarray(x)\n"
        )
        found = scan("src/repro/core/chain_router.py", src)
        assert rules_of(found) == ["host-sync"]
        assert "np.asarray" in found[0].message

    def test_np_asarray_in_untraced_host_code_ok(self):
        # the per-op processors sync on purpose (billed to the profiler)
        src = "import numpy as np\n\ndef host_side(x):\n    return np.asarray(x)\n"
        assert scan("src/repro/core/executor.py", src) == []

    def test_scan_body_is_traced_scope(self):
        src = (
            "import jax\n\n"
            "def cycle(xs):\n"
            "    def body(carry, x):\n"
            "        return carry, float(x)\n"
            "    return jax.lax.scan(body, 0, xs)\n"
        )
        found = scan("src/repro/core/executor.py", src)
        assert rules_of(found) == ["host-sync"]
        assert "float()" in found[0].message

    def test_tracer_bool_branch_flagged(self):
        src = (
            "import jax\nimport jax.numpy as jnp\n\n"
            "@jax.jit\ndef f(x):\n"
            "    if jnp.any(x > 0):\n"
            "        return x\n"
            "    return -x\n"
        )
        found = scan("src/repro/models/transformer.py", src)
        assert rules_of(found) == ["host-sync"]
        assert "lax.cond" in found[0].message

    def test_jnp_in_traced_scope_ok(self):
        src = (
            "import jax\nimport jax.numpy as jnp\n\n"
            "@jax.jit\ndef f(x):\n    return jnp.asarray(x) + 1\n"
        )
        assert scan("src/repro/core/executor.py", src) == []


class TestRngRules:
    def test_literal_key_in_library_flagged(self):
        src = "import jax\n\ndef f():\n    return jax.random.PRNGKey(0)\n"
        assert rules_of(scan("src/repro/core/executor.py", src)) == [
            "rng-literal-key"]

    def test_literal_key_in_tests_ok(self):
        src = "import jax\n\ndef f():\n    return jax.random.PRNGKey(0)\n"
        assert scan("tests/test_foo.py", src) == []

    def test_key_from_caller_ok(self):
        src = "import jax\n\ndef f(seed):\n    return jax.random.PRNGKey(seed)\n"
        assert scan("src/repro/core/executor.py", src) == []

    def test_key_reuse_flagged(self):
        src = (
            "import jax\n\n"
            "def f(key, a, b):\n"
            "    x = jax.random.normal(key, (3,))\n"
            "    y = jax.random.uniform(key, (3,))\n"
            "    return x + y\n"
        )
        found = scan("src/repro/train/pool.py", src)
        assert rules_of(found) == ["rng-key-reuse"]
        assert "'key'" in found[0].message

    def test_key_split_ok(self):
        src = (
            "import jax\n\n"
            "def f(key, a, b):\n"
            "    k1, k2 = jax.random.split(key)\n"
            "    x = jax.random.normal(k1, (3,))\n"
            "    y = jax.random.uniform(k2, (3,))\n"
            "    return x + y\n"
        )
        assert scan("src/repro/train/pool.py", src) == []

    def test_nested_function_scopes_independent(self):
        # one sampler per function: no reuse even though the names collide
        src = (
            "import jax\n\n"
            "def outer(key):\n"
            "    x = jax.random.normal(key, (3,))\n"
            "    def inner(key):\n"
            "        return jax.random.uniform(key, (3,))\n"
            "    return x, inner\n"
        )
        assert scan("src/repro/train/pool.py", src) == []


class TestBroadExceptRule:
    def test_bare_except_in_core_flagged(self):
        src = "def f():\n    try:\n        g()\n    except:\n        pass\n"
        assert rules_of(scan("src/repro/core/scheduler.py", src)) == [
            "broad-except"]

    def test_except_exception_in_models_flagged(self):
        src = (
            "def f():\n    try:\n        g()\n"
            "    except Exception:\n        pass\n"
        )
        assert rules_of(scan("src/repro/models/moe.py", src)) == [
            "broad-except"]

    def test_narrow_except_ok(self):
        src = (
            "def f():\n    try:\n        g()\n"
            "    except (ValueError, KeyError):\n        pass\n"
        )
        assert scan("src/repro/core/scheduler.py", src) == []

    def test_broad_except_outside_serving_ok(self):
        src = "def f():\n    try:\n        g()\n    except Exception:\n        pass\n"
        assert scan("src/repro/launch/dryrun.py", src) == []


class TestDefaultsRules:
    def test_mutable_default_flagged(self):
        src = "def f(x, acc=[]):\n    acc.append(x)\n    return acc\n"
        assert rules_of(scan("src/repro/core/util.py", src)) == [
            "mutable-default"]

    def test_none_default_ok(self):
        src = "def f(x, acc=None):\n    return acc or [x]\n"
        assert scan("src/repro/core/util.py", src) == []

    def test_implicit_optional_dataclass_field_flagged(self):
        src = (
            "import dataclasses\nimport numpy as np\n\n"
            "@dataclasses.dataclass\nclass Req:\n"
            "    active: np.ndarray = None\n"
        )
        found = scan("src/repro/core/executor.py", src)
        assert rules_of(found) == ["dataclass-pytree"]
        assert "Optional" in found[0].message

    def test_explicit_optional_dataclass_field_ok(self):
        src = (
            "import dataclasses\nfrom typing import Optional\n"
            "import numpy as np\n\n"
            "@dataclasses.dataclass\nclass Req:\n"
            "    active: Optional[np.ndarray] = None\n"
        )
        assert scan("src/repro/core/executor.py", src) == []

    def test_mutable_dataclass_field_flagged(self):
        src = (
            "import dataclasses\n\n"
            "@dataclasses.dataclass\nclass Req:\n"
            "    extras: dict = {}\n"
        )
        found = scan("src/repro/core/executor.py", src)
        assert rules_of(found) == ["dataclass-pytree"]
        assert "default_factory" in found[0].message


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------
class TestSuppressions:
    SRC = (
        "import jax\n\n"
        "def f(x):\n"
        "    return jax.device_get(x)"
        "  # speclint: disable=host-sync -- sanctioned transfer\n"
    )

    def test_inline_suppression_with_reason(self):
        path = "src/repro/core/executor.py"
        found = scan(path, self.SRC)
        sups, bad = collect_suppressions(self.SRC, path)
        assert bad == []
        assert apply_suppressions(found, {path: sups}) == []

    def test_suppression_without_reason_is_finding(self):
        src = self.SRC.replace(" -- sanctioned transfer", "")
        path = "src/repro/core/executor.py"
        sups, bad = collect_suppressions(src, path)
        assert rules_of(bad) == ["bad-suppression"]
        # and the original finding is NOT suppressed
        assert rules_of(apply_suppressions(scan(path, src), {path: sups})) \
            == ["host-sync"]

    def test_standalone_comment_covers_next_line(self):
        src = (
            "import jax\n\n"
            "def f(x):\n"
            "    # speclint: disable=host-sync -- the one transfer\n"
            "    return jax.device_get(x)\n"
        )
        path = "src/repro/core/executor.py"
        sups, bad = collect_suppressions(src, path)
        assert bad == []
        assert apply_suppressions(scan(path, src), {path: sups}) == []

    def test_wrong_rule_does_not_suppress(self):
        src = self.SRC.replace("disable=host-sync", "disable=rng-literal-key")
        path = "src/repro/core/executor.py"
        sups, _ = collect_suppressions(src, path)
        assert rules_of(apply_suppressions(scan(path, src), {path: sups})) \
            == ["host-sync"]


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------
class TestBaseline:
    def _finding(self):
        return Finding(rule="host-sync", path="src/repro/core/executor.py",
                       line=10, message="m", snippet="x = jax.device_get(y)")

    def test_fingerprint_survives_line_drift(self):
        a = self._finding()
        b = Finding(rule=a.rule, path=a.path, line=99, message=a.message,
                    snippet="  x =   jax.device_get(y)")
        assert a.fingerprint() == b.fingerprint()

    def test_roundtrip_and_filter(self, tmp_path):
        f = self._finding()
        p = tmp_path / "bl.json"
        Baseline.write(p, [f])
        data = json.loads(p.read_text())
        data["findings"][0]["reason"] = "grandfathered in PR 8"
        p.write_text(json.dumps(data))
        bl = Baseline.load(p)
        assert bl.validate() == []
        new, matched = bl.filter([f])
        assert new == [] and matched == [f.fingerprint()]
        assert bl.stale(matched) == []

    def test_entry_without_reason_is_finding(self, tmp_path):
        p = tmp_path / "bl.json"
        Baseline.write(p, [self._finding()])  # reasons left empty
        assert rules_of(Baseline.load(p).validate()) == ["bad-baseline"]

    def test_stale_entries_reported(self, tmp_path):
        p = tmp_path / "bl.json"
        Baseline.write(p, [self._finding()])
        bl = Baseline.load(p)
        new, matched = bl.filter([])  # finding fixed meanwhile
        assert bl.stale(matched) == [self._finding().fingerprint()]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def _write_tree(root, files):
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)


class TestCli:
    CLEAN = {"core/executor.py": "import jax.numpy as jnp\n\n"
                                 "def f(x):\n    return jnp.sum(x)\n"}
    DIRTY = {"core/executor.py": "import jax\n\n"
                                 "def f(x):\n    return jax.device_get(x)\n"}

    def test_exit_zero_on_clean_tree(self, tmp_path, capsys):
        _write_tree(tmp_path, self.CLEAN)
        rc = speclint_main([str(tmp_path), "--tiers", "ast",
                            "--baseline", str(tmp_path / "bl.json")])
        assert rc == 0
        assert "speclint: clean" in capsys.readouterr().out

    def test_exit_one_on_findings(self, tmp_path, capsys):
        _write_tree(tmp_path, self.DIRTY)
        rc = speclint_main([str(tmp_path), "--tiers", "ast",
                            "--baseline", str(tmp_path / "bl.json")])
        assert rc == 1
        assert "[host-sync]" in capsys.readouterr().out

    def test_exit_two_on_unknown_tier(self, tmp_path):
        assert speclint_main([str(tmp_path), "--tiers", "nope"]) == 2

    def test_exit_two_on_missing_paths(self):
        assert speclint_main(["--tiers", "ast"]) == 2

    def test_list_rules(self, capsys):
        assert speclint_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rule in ("host-sync", "pallas-oob", "runtime-transfer-per-cycle"):
            assert rule in out

    def test_write_baseline_then_justify_then_clean(self, tmp_path, capsys):
        _write_tree(tmp_path, self.DIRTY)
        bl = tmp_path / "bl.json"
        assert speclint_main([str(tmp_path), "--tiers", "ast",
                              "--baseline", str(bl),
                              "--write-baseline"]) == 0
        # unjustified baseline entries are themselves findings
        assert speclint_main([str(tmp_path), "--tiers", "ast",
                              "--baseline", str(bl)]) == 1
        assert "[bad-baseline]" in capsys.readouterr().out
        data = json.loads(bl.read_text())
        for e in data["findings"]:
            e["reason"] = "pre-existing; tracked for PR 9"
        bl.write_text(json.dumps(data))
        assert speclint_main([str(tmp_path), "--tiers", "ast",
                              "--baseline", str(bl)]) == 0
        assert "(1 baselined)" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Meta rule: kernel / oracle / parity-test coverage
# ---------------------------------------------------------------------------
class TestMetaRule:
    KERNEL = (
        "from jax.experimental import pallas as pl\n\n"
        "def fancy_pallas(x):\n"
        "    return pl.pallas_call(_k, out_shape=x)(x)\n"
    )

    def test_missing_oracle_flagged(self):
        found = meta_rules.run([("src/repro/kernels/fancy.py", self.KERNEL)],
                               "def other_ref(x):\n    return x\n", [])
        assert rules_of(found) == ["kernel-no-oracle"]
        assert "fancy_ref" in found[0].message

    def test_missing_parity_test_flagged(self):
        found = meta_rules.run([("src/repro/kernels/fancy.py", self.KERNEL)],
                               "def fancy_ref(x):\n    return x\n", [])
        assert rules_of(found) == ["kernel-no-parity-test"]

    def test_oracle_plus_test_ok(self):
        found = meta_rules.run(
            [("src/repro/kernels/fancy.py", self.KERNEL)],
            "def fancy_ref(x):\n    return x\n",
            [("tests/test_k.py", "from repro.kernels.ref import fancy_ref\n")])
        assert found == []

    def test_real_tree_is_green(self):
        from pathlib import Path
        found = meta_rules.load_and_run(
            [Path("src")], [Path("tests")])
        assert found == [], [f.format() for f in found]


# ---------------------------------------------------------------------------
# Pallas bounds tier
# ---------------------------------------------------------------------------
class TestPallasBounds:
    def test_real_kernels_in_bounds(self):
        found = pallas_bounds.run()
        assert found == [], [f.format() for f in found]

    def test_injected_oob_index_map_flagged(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def bad_launcher(x):
            blk = 4
            n = x.shape[0] // blk
            return pl.pallas_call(
                lambda x_ref, o_ref: None,
                grid=(n,),
                in_specs=[pl.BlockSpec((blk,), lambda i: (i + 1,))],  # off by one
                out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(x)

        found = pallas_bounds.check_launch(
            bad_launcher, jnp.zeros((16,), jnp.float32))
        assert "pallas-oob" in rules_of(found)
        assert "outside extent 16" in found[0].message

    def test_rank_mismatch_flagged(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def bad_rank(x):
            return pl.pallas_call(
                lambda x_ref, o_ref: None,
                grid=(2,),
                in_specs=[pl.BlockSpec((4, 4), lambda i: (i, 0))],  # x is 1-D
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            )(x)

        found = pallas_bounds.check_launch(
            bad_rank, jnp.zeros((16,), jnp.float32))
        assert "pallas-spec-arity" in rules_of(found)


# ---------------------------------------------------------------------------
# jaxpr tier primitives (fast: no pool, traces toy programs)
# ---------------------------------------------------------------------------
class TestJaxprPrimitives:
    def test_callback_primitive_detected(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis import jaxpr_rules

        def leaky(x):
            jax.debug.print("x={}", x)  # lowers to a callback primitive
            return jnp.sum(x)

        found = jaxpr_rules.check_entry_point(
            "leaky", leaky, (jax.ShapeDtypeStruct((4,), jnp.float32),),
            "src/repro/core/executor.py")
        assert rules_of(found) == ["jaxpr-callback"]

    def test_clean_program_passes(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis import jaxpr_rules

        found = jaxpr_rules.check_entry_point(
            "clean", lambda x: jnp.sum(x) * 2,
            (jax.ShapeDtypeStruct((4,), jnp.float32),),
            "src/repro/core/executor.py")
        assert found == []


# ---------------------------------------------------------------------------
# Dynamic tiers against the real fused cycle (jits a tiny pool)
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestDynamicTiers:
    @pytest.fixture(scope="class")
    def cap(self):
        from repro.analysis import harness
        return harness.capture_fused_linear()

    def test_fused_cycle_jaxpr_clean(self, cap):
        from repro.analysis import jaxpr_rules
        found = jaxpr_rules.run(cap)
        assert found == [], [f.format() for f in found]

    def test_fused_cycle_hlo_and_runtime_clean(self, cap):
        from repro.analysis import hlo_rules
        found = hlo_rules.run(cap)
        assert found == [], [f.format() for f in found]
